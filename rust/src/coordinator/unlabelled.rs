//! Unlabelled online learning + unseen-class detection — the paper's §7
//! research directions: "experimentation with the TM's classification
//! confidence to apply feedback when using unlabelled online data, as
//! well as using the class confidences from each class to determine if
//! unlabelled data may belong to an unseen classification."
//!
//! Confidence is the vote margin: `margin = v_best − v_runner_up` of the
//! clamped class sums (§2: "a majority vote gives an indication of class
//! confidence"). Pseudo-labelling trains on the predicted class when the
//! margin clears a threshold; the unseen-class detector flags datapoints
//! whose *best* sum is low (no class's clauses claim them).

use crate::tm::bitplane::{BitPlanes, PlaneBatch};
use crate::tm::clause::{EvalMode, Input};
use crate::tm::engine::train_step_fast_with;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rescore::RescoreCache;
use crate::tm::rng::{StepRands, Xoshiro256};
use crate::tm::train_planes::TrainScratch;
use anyhow::{ensure, Result};

/// Vote-margin confidence of one inference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Confidence {
    pub prediction: usize,
    /// Clamped sum of the predicted class.
    pub best_sum: i32,
    /// best − runner-up margin (0 when only one active class).
    pub margin: i32,
}

/// Compute prediction + confidence from one datapoint.
pub fn confidence(tm: &mut MultiTm, x: &Input, params: &TmParams) -> Confidence {
    let (sums, pred) = tm.infer(x, params);
    let best = sums[pred];
    let runner_up = sums
        .iter()
        .enumerate()
        .filter(|(c, _)| *c != pred)
        .map(|(_, &v)| v)
        .max()
        .unwrap_or(best);
    Confidence { prediction: pred, best_sum: best, margin: best - runner_up }
}

/// Pseudo-labelling policy (§7): train on the TM's own prediction when
/// the vote margin is at least `min_margin`.
#[derive(Debug, Clone, Copy)]
pub struct PseudoLabelPolicy {
    pub min_margin: i32,
}

/// Statistics from one unlabelled online pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct UnlabelledStats {
    pub seen: usize,
    pub trained: usize,
    /// Of the trained datapoints, how many pseudo-labels were actually
    /// correct (requires ground truth; reported for analysis only).
    pub pseudo_correct: usize,
}

/// One unlabelled online pass: for each row, infer; if confident, apply a
/// training step toward the predicted class. Labels are used only to
/// report pseudo-label precision.
pub fn unlabelled_pass(
    tm: &mut MultiTm,
    data: &[(Input, usize)],
    params_infer: &TmParams,
    params_train: &TmParams,
    policy: PseudoLabelPolicy,
    rng: &mut Xoshiro256,
    rands: &mut StepRands,
) -> Result<UnlabelledStats> {
    let shape = tm.shape().clone();
    let mut stats = UnlabelledStats::default();
    // Pseudo-labelling is inherently per-step (each step's label is the
    // prediction the previous steps trained), so the lane engine does
    // not apply — but the step scratch still hoists the per-step sign
    // allocation out of the loop.
    let mut scratch = TrainScratch::new();
    for (x, y) in data {
        stats.seen += 1;
        let c = confidence(tm, x, params_infer);
        if c.margin >= policy.min_margin {
            rands.refill(rng, &shape);
            // Word-parallel engine, bit-identical to the scalar oracle.
            train_step_fast_with(tm, x, c.prediction, params_train, rands, &mut scratch);
            stats.trained += 1;
            if c.prediction == *y {
                stats.pseudo_correct += 1;
            }
        }
    }
    Ok(stats)
}

/// Interleaved unlabelled learning with continuous monitoring: run the
/// pseudo-label pass in chunks of `rescore_every` datapoints, re-scoring
/// the whole cached `eval` batch after each chunk through the
/// incremental dirty-clause engine. Returns the aggregated pass stats
/// plus the accuracy trajectory — each point bit-identical to a cold
/// `accuracy_planes` pass at the same step (pseudo-label training
/// converges fast under the margin gate, so most chunks flip few TA
/// actions and the re-score cost collapses with the dirty fraction).
pub fn unlabelled_pass_monitored(
    tm: &mut MultiTm,
    data: &[(Input, usize)],
    params_infer: &TmParams,
    params_train: &TmParams,
    policy: PseudoLabelPolicy,
    rng: &mut Xoshiro256,
    rands: &mut StepRands,
    eval: &PlaneBatch,
    rescore_every: usize,
    cache: &mut RescoreCache,
) -> Result<(UnlabelledStats, Vec<f64>)> {
    ensure!(rescore_every > 0, "rescore_every must be positive");
    let mut total = UnlabelledStats::default();
    let mut curve = Vec::with_capacity(data.len().div_ceil(rescore_every));
    for chunk in data.chunks(rescore_every) {
        let s = unlabelled_pass(tm, chunk, params_infer, params_train, policy, rng, rands)?;
        total.seen += s.seen;
        total.trained += s.trained;
        total.pseudo_correct += s.pseudo_correct;
        curve.push(cache.accuracy(tm, eval, params_infer));
    }
    Ok((total, curve))
}

/// Unseen-class detector (§7): a datapoint whose best clamped sum is
/// below `min_best_sum` belongs to no known class's clause patterns.
#[derive(Debug, Clone, Copy)]
pub struct UnseenClassDetector {
    pub min_best_sum: i32,
}

impl UnseenClassDetector {
    /// Does the machine consider this datapoint foreign?
    pub fn is_unseen(&self, tm: &mut MultiTm, x: &Input, params: &TmParams) -> bool {
        confidence(tm, x, params).best_sum < self.min_best_sum
    }

    /// Flag rate over a set — sample-sliced: the batch is transposed once
    /// and every class sum computed 64 rows per AND; a row is flagged iff
    /// its best clamped sum (max over active classes, exactly
    /// [`confidence`]'s `best_sum`) is below the threshold.
    pub fn flag_rate(
        &self,
        tm: &mut MultiTm,
        data: &[(Input, usize)],
        params: &TmParams,
    ) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let planes = BitPlanes::from_labelled(tm.shape(), data);
        let sums = tm.evaluate_planes(&planes, params, EvalMode::Infer);
        Self::rate_from_sums(self.min_best_sum, &sums, data.len(), params.active_classes)
    }

    /// [`UnseenClassDetector::flag_rate`] off a cached transpose through
    /// the incremental engine — for drivers that re-run the detector over
    /// the same batch while training interleaves (drift watch): only
    /// dirtied clauses are re-ANDed, and the rate is identical to the
    /// cold path's.
    pub fn flag_rate_planes(
        &self,
        tm: &MultiTm,
        cache: &mut RescoreCache,
        planes: &BitPlanes,
        params: &TmParams,
    ) -> f64 {
        if planes.is_empty() {
            return 0.0;
        }
        let sums = cache.evaluate(tm, planes, params, EvalMode::Infer);
        Self::rate_from_sums(self.min_best_sum, &sums, planes.len(), params.active_classes)
    }

    fn rate_from_sums(min_best: i32, sums: &[i32], n: usize, nc: usize) -> f64 {
        let flagged = (0..n)
            .filter(|&i| {
                let best = (0..nc).map(|c| sums[c * n + i]).max().unwrap_or(0);
                best < min_best
            })
            .count();
        flagged as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::{BlockPlan, SetAllocation};
    use crate::data::filter::ClassFilter;
    use crate::data::iris;
    use crate::tm::engine::train_step_fast;
    use crate::tm::params::TmShape;

    fn trained_on(
        data: &[(Input, usize)],
        shape: &TmShape,
        params: &TmParams,
        epochs: usize,
        seed: u64,
    ) -> MultiTm {
        let mut tm = MultiTm::new(shape).unwrap();
        let mut rng = Xoshiro256::new(seed);
        let mut rands = StepRands::draw(&mut rng, shape);
        for _ in 0..epochs {
            for (x, y) in data {
                rands.refill(&mut rng, shape);
                train_step_fast(&mut tm, x, *y, params, &rands);
            }
        }
        tm
    }

    #[test]
    fn confidence_margins_are_consistent() {
        let shape = TmShape::iris();
        let params = TmParams::paper_offline(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.pack(&shape);
        let mut tm = trained_on(&train, &shape, &params, 10, 1);
        for (x, _) in train.iter().take(20) {
            let c = confidence(&mut tm, x, &params);
            assert!(c.margin >= 0);
            assert!(c.best_sum.abs() <= params.t);
            let (sums, pred) = tm.infer(x, &params);
            assert_eq!(c.prediction, pred);
            assert_eq!(c.best_sum, sums[pred]);
        }
    }

    #[test]
    fn pseudo_labelling_trains_only_confident_rows() {
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.truncate(20).pack(&shape);
        let online = sets.online.pack(&shape);
        let mut tm = trained_on(&train, &shape, &p_off, 10, 2);
        let mut rng = Xoshiro256::new(3);
        let mut rands = StepRands::draw(&mut rng, &shape);
        // Impossible margin: nothing trains.
        let stats = unlabelled_pass(
            &mut tm,
            &online,
            &p_off,
            &p_on,
            PseudoLabelPolicy { min_margin: 2 * p_off.t + 1 },
            &mut rng,
            &mut rands,
        )
        .unwrap();
        assert_eq!(stats.trained, 0);
        assert_eq!(stats.seen, 60);
        // Margin 0: everything trains.
        let stats = unlabelled_pass(
            &mut tm,
            &online,
            &p_off,
            &p_on,
            PseudoLabelPolicy { min_margin: 0 },
            &mut rng,
            &mut rands,
        )
        .unwrap();
        assert_eq!(stats.trained, 60);
        assert!(stats.pseudo_correct > 30, "pseudo-labels mostly right");
    }

    #[test]
    fn confident_pseudo_labels_are_more_precise() {
        // Precision of pseudo-labels must rise with the margin threshold.
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.truncate(20).pack(&shape);
        let online = sets.online.pack(&shape);
        let mut precision = Vec::new();
        for margin in [0, 3] {
            let mut tm = trained_on(&train, &shape, &p_off, 10, 2);
            let mut rng = Xoshiro256::new(4);
            let mut rands = StepRands::draw(&mut rng, &shape);
            let s = unlabelled_pass(
                &mut tm,
                &online,
                &p_off,
                &p_on,
                PseudoLabelPolicy { min_margin: margin },
                &mut rng,
                &mut rands,
            )
            .unwrap();
            assert!(s.trained > 0);
            precision.push(s.pseudo_correct as f64 / s.trained as f64);
        }
        assert!(
            precision[1] >= precision[0],
            "margin 3 precision {:.3} !>= margin 0 {:.3}",
            precision[1],
            precision[0]
        );
    }

    #[test]
    fn unlabelled_learning_improves_over_frozen() {
        // Averaged over orderings: pseudo-label online learning should
        // beat no online learning on the online set.
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let orderings = crate::data::blocks::all_orderings(5);
        let mut gain = 0.0;
        let n = 6;
        for (i, ord) in orderings.iter().take(n).enumerate() {
            let sets = plan.sets(ord, SetAllocation::paper()).unwrap();
            let train = sets.offline.truncate(20).pack(&shape);
            let online = sets.online.pack(&shape);
            let mut tm = trained_on(&train, &shape, &p_off, 10, 5 + i as u64);
            let frozen_acc = tm.accuracy(&online, &p_off);
            let mut rng = Xoshiro256::new(50 + i as u64);
            let mut rands = StepRands::draw(&mut rng, &shape);
            for _ in 0..8 {
                unlabelled_pass(
                    &mut tm,
                    &online,
                    &p_off,
                    &p_on,
                    PseudoLabelPolicy { min_margin: 2 },
                    &mut rng,
                    &mut rands,
                )
                .unwrap();
            }
            gain += tm.accuracy(&online, &p_off) - frozen_acc;
        }
        gain /= n as f64;
        assert!(gain > 0.0, "unlabelled learning mean gain {gain:.3}");
    }

    /// The monitored pass equals running plain `unlabelled_pass` chunk by
    /// chunk with a cold full-set accuracy after each chunk — same stats,
    /// bit-identical curve.
    #[test]
    fn monitored_pass_matches_cold_chunked_oracle() {
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.truncate(20).pack(&shape);
        let online = sets.online.pack(&shape);
        let eval = PlaneBatch::from_labelled(&shape, &sets.validation.pack(&shape));
        let policy = PseudoLabelPolicy { min_margin: 2 };

        let mut a = trained_on(&train, &shape, &p_off, 10, 2);
        let mut rng_a = Xoshiro256::new(6);
        let mut rands_a = StepRands::draw(&mut rng_a, &shape);
        let mut cache = RescoreCache::new();
        let (stats_a, curve_a) = unlabelled_pass_monitored(
            &mut a, &online, &p_off, &p_on, policy, &mut rng_a, &mut rands_a, &eval, 10,
            &mut cache,
        )
        .unwrap();
        assert_eq!(curve_a.len(), 6, "60 rows / 10 per chunk");

        let mut b = trained_on(&train, &shape, &p_off, 10, 2);
        let mut rng_b = Xoshiro256::new(6);
        let mut rands_b = StepRands::draw(&mut rng_b, &shape);
        let mut stats_b = UnlabelledStats::default();
        let mut curve_b = Vec::new();
        for chunk in online.chunks(10) {
            let s = unlabelled_pass(
                &mut b, chunk, &p_off, &p_on, policy, &mut rng_b, &mut rands_b,
            )
            .unwrap();
            stats_b.seen += s.seen;
            stats_b.trained += s.trained;
            stats_b.pseudo_correct += s.pseudo_correct;
            curve_b.push(b.accuracy_planes(&eval, &p_off));
        }
        assert_eq!(curve_a, curve_b, "bit-identical accuracy trajectories");
        assert_eq!(stats_a.seen, stats_b.seen);
        assert_eq!(stats_a.trained, stats_b.trained);
        assert_eq!(stats_a.pseudo_correct, stats_b.pseudo_correct);
        assert!(cache.stats().clean_clauses > 0, "incremental path engaged");
    }

    #[test]
    fn cached_flag_rate_matches_cold_flag_rate() {
        let shape = TmShape::iris();
        let params = TmParams::paper_offline(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.pack(&shape);
        let online = sets.online.pack(&shape);
        let mut tm = trained_on(&train, &shape, &params, 10, 3);
        let det = UnseenClassDetector { min_best_sum: 2 };
        let planes = BitPlanes::from_labelled(&shape, &online);
        let mut cache = RescoreCache::new();
        for round in 0..3 {
            let cold = det.flag_rate(&mut tm, &online, &params);
            let cached = det.flag_rate_planes(&tm, &mut cache, &planes, &params);
            assert_eq!(cold, cached, "round {round}");
            // Nudge the machine between rounds so later rounds exercise
            // the dirty path, not just a clean cache.
            tm.set_clause_fault(round % 3, round, Some(round % 2 == 0));
        }
    }

    #[test]
    fn detector_flags_unseen_class_more_than_known() {
        // Train on two prototype classes of a 3-class synthetic dataset;
        // rows of the withheld prototype must be flagged as unseen far
        // more often than rows of the known classes. (On iris under
        // binary encoding, withheld-setosa rows alias into versicolor
        // clauses — the synthetic task isolates the mechanism.)
        let shape = TmShape { classes: 3, max_clauses: 8, features: 16, states: 100 };
        let mut params = TmParams::paper_offline(&shape);
        params.s = 3.0; // specific clauses -> crisp confidence signal
        params.active_classes = 2;
        let d = crate::data::synthetic::prototype_dataset(3, 60, 16, 0.05, 9).unwrap();
        let known_train = ClassFilter::removing(2).apply(&d.truncate(120));
        let train = known_train.pack(&shape);
        let mut tm = trained_on(&train, &shape, &params, 20, 7);
        let det = UnseenClassDetector { min_best_sum: 1 };
        let tail = d.subset(&(120..180).collect::<Vec<_>>());
        let unseen_rows = ClassFilter::removing(0)
            .apply(&ClassFilter::removing(1).apply(&tail))
            .pack(&shape);
        let known_rows = ClassFilter::removing(2).apply(&tail).pack(&shape);
        assert!(!unseen_rows.is_empty() && !known_rows.is_empty());
        let unseen_rate = det.flag_rate(&mut tm, &unseen_rows, &params);
        let known_rate = det.flag_rate(&mut tm, &known_rows, &params);
        assert!(
            unseen_rate > known_rate + 0.2,
            "unseen {unseen_rate:.2} vs known {known_rate:.2}"
        );
    }
}
