//! Unlabelled online learning + unseen-class detection — the paper's §7
//! research directions: "experimentation with the TM's classification
//! confidence to apply feedback when using unlabelled online data, as
//! well as using the class confidences from each class to determine if
//! unlabelled data may belong to an unseen classification."
//!
//! Confidence is the vote margin: `margin = v_best − v_runner_up` of the
//! clamped class sums (§2: "a majority vote gives an indication of class
//! confidence"). Pseudo-labelling trains on the predicted class when the
//! margin clears a threshold; the unseen-class detector flags datapoints
//! whose *best* sum is low (no class's clauses claim them).

use crate::tm::bitplane::BitPlanes;
use crate::tm::clause::{EvalMode, Input};
use crate::tm::engine::train_step_fast;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::{StepRands, Xoshiro256};
use anyhow::Result;

/// Vote-margin confidence of one inference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Confidence {
    pub prediction: usize,
    /// Clamped sum of the predicted class.
    pub best_sum: i32,
    /// best − runner-up margin (0 when only one active class).
    pub margin: i32,
}

/// Compute prediction + confidence from one datapoint.
pub fn confidence(tm: &mut MultiTm, x: &Input, params: &TmParams) -> Confidence {
    let (sums, pred) = tm.infer(x, params);
    let best = sums[pred];
    let runner_up = sums
        .iter()
        .enumerate()
        .filter(|(c, _)| *c != pred)
        .map(|(_, &v)| v)
        .max()
        .unwrap_or(best);
    Confidence { prediction: pred, best_sum: best, margin: best - runner_up }
}

/// Pseudo-labelling policy (§7): train on the TM's own prediction when
/// the vote margin is at least `min_margin`.
#[derive(Debug, Clone, Copy)]
pub struct PseudoLabelPolicy {
    pub min_margin: i32,
}

/// Statistics from one unlabelled online pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct UnlabelledStats {
    pub seen: usize,
    pub trained: usize,
    /// Of the trained datapoints, how many pseudo-labels were actually
    /// correct (requires ground truth; reported for analysis only).
    pub pseudo_correct: usize,
}

/// One unlabelled online pass: for each row, infer; if confident, apply a
/// training step toward the predicted class. Labels are used only to
/// report pseudo-label precision.
pub fn unlabelled_pass(
    tm: &mut MultiTm,
    data: &[(Input, usize)],
    params_infer: &TmParams,
    params_train: &TmParams,
    policy: PseudoLabelPolicy,
    rng: &mut Xoshiro256,
    rands: &mut StepRands,
) -> Result<UnlabelledStats> {
    let shape = tm.shape().clone();
    let mut stats = UnlabelledStats::default();
    for (x, y) in data {
        stats.seen += 1;
        let c = confidence(tm, x, params_infer);
        if c.margin >= policy.min_margin {
            rands.refill(rng, &shape);
            // Word-parallel engine, bit-identical to the scalar oracle.
            train_step_fast(tm, x, c.prediction, params_train, rands);
            stats.trained += 1;
            if c.prediction == *y {
                stats.pseudo_correct += 1;
            }
        }
    }
    Ok(stats)
}

/// Unseen-class detector (§7): a datapoint whose best clamped sum is
/// below `min_best_sum` belongs to no known class's clause patterns.
#[derive(Debug, Clone, Copy)]
pub struct UnseenClassDetector {
    pub min_best_sum: i32,
}

impl UnseenClassDetector {
    /// Does the machine consider this datapoint foreign?
    pub fn is_unseen(&self, tm: &mut MultiTm, x: &Input, params: &TmParams) -> bool {
        confidence(tm, x, params).best_sum < self.min_best_sum
    }

    /// Flag rate over a set — sample-sliced: the batch is transposed once
    /// and every class sum computed 64 rows per AND; a row is flagged iff
    /// its best clamped sum (max over active classes, exactly
    /// [`confidence`]'s `best_sum`) is below the threshold.
    pub fn flag_rate(
        &self,
        tm: &mut MultiTm,
        data: &[(Input, usize)],
        params: &TmParams,
    ) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let planes = BitPlanes::from_labelled(tm.shape(), data);
        let sums = tm.evaluate_planes(&planes, params, EvalMode::Infer);
        let n = data.len();
        let nc = params.active_classes;
        let flagged = (0..n)
            .filter(|&i| {
                let best = (0..nc).map(|c| sums[c * n + i]).max().unwrap_or(0);
                best < self.min_best_sum
            })
            .count();
        flagged as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::{BlockPlan, SetAllocation};
    use crate::data::filter::ClassFilter;
    use crate::data::iris;
    use crate::tm::params::TmShape;

    fn trained_on(
        data: &[(Input, usize)],
        shape: &TmShape,
        params: &TmParams,
        epochs: usize,
        seed: u64,
    ) -> MultiTm {
        let mut tm = MultiTm::new(shape).unwrap();
        let mut rng = Xoshiro256::new(seed);
        let mut rands = StepRands::draw(&mut rng, shape);
        for _ in 0..epochs {
            for (x, y) in data {
                rands.refill(&mut rng, shape);
                train_step_fast(&mut tm, x, *y, params, &rands);
            }
        }
        tm
    }

    #[test]
    fn confidence_margins_are_consistent() {
        let shape = TmShape::iris();
        let params = TmParams::paper_offline(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.pack(&shape);
        let mut tm = trained_on(&train, &shape, &params, 10, 1);
        for (x, _) in train.iter().take(20) {
            let c = confidence(&mut tm, x, &params);
            assert!(c.margin >= 0);
            assert!(c.best_sum.abs() <= params.t);
            let (sums, pred) = tm.infer(x, &params);
            assert_eq!(c.prediction, pred);
            assert_eq!(c.best_sum, sums[pred]);
        }
    }

    #[test]
    fn pseudo_labelling_trains_only_confident_rows() {
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.truncate(20).pack(&shape);
        let online = sets.online.pack(&shape);
        let mut tm = trained_on(&train, &shape, &p_off, 10, 2);
        let mut rng = Xoshiro256::new(3);
        let mut rands = StepRands::draw(&mut rng, &shape);
        // Impossible margin: nothing trains.
        let stats = unlabelled_pass(
            &mut tm,
            &online,
            &p_off,
            &p_on,
            PseudoLabelPolicy { min_margin: 2 * p_off.t + 1 },
            &mut rng,
            &mut rands,
        )
        .unwrap();
        assert_eq!(stats.trained, 0);
        assert_eq!(stats.seen, 60);
        // Margin 0: everything trains.
        let stats = unlabelled_pass(
            &mut tm,
            &online,
            &p_off,
            &p_on,
            PseudoLabelPolicy { min_margin: 0 },
            &mut rng,
            &mut rands,
        )
        .unwrap();
        assert_eq!(stats.trained, 60);
        assert!(stats.pseudo_correct > 30, "pseudo-labels mostly right");
    }

    #[test]
    fn confident_pseudo_labels_are_more_precise() {
        // Precision of pseudo-labels must rise with the margin threshold.
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.truncate(20).pack(&shape);
        let online = sets.online.pack(&shape);
        let mut precision = Vec::new();
        for margin in [0, 3] {
            let mut tm = trained_on(&train, &shape, &p_off, 10, 2);
            let mut rng = Xoshiro256::new(4);
            let mut rands = StepRands::draw(&mut rng, &shape);
            let s = unlabelled_pass(
                &mut tm,
                &online,
                &p_off,
                &p_on,
                PseudoLabelPolicy { min_margin: margin },
                &mut rng,
                &mut rands,
            )
            .unwrap();
            assert!(s.trained > 0);
            precision.push(s.pseudo_correct as f64 / s.trained as f64);
        }
        assert!(
            precision[1] >= precision[0],
            "margin 3 precision {:.3} !>= margin 0 {:.3}",
            precision[1],
            precision[0]
        );
    }

    #[test]
    fn unlabelled_learning_improves_over_frozen() {
        // Averaged over orderings: pseudo-label online learning should
        // beat no online learning on the online set.
        let shape = TmShape::iris();
        let p_off = TmParams::paper_offline(&shape);
        let p_on = TmParams::paper_online(&shape);
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
        let orderings = crate::data::blocks::all_orderings(5);
        let mut gain = 0.0;
        let n = 6;
        for (i, ord) in orderings.iter().take(n).enumerate() {
            let sets = plan.sets(ord, SetAllocation::paper()).unwrap();
            let train = sets.offline.truncate(20).pack(&shape);
            let online = sets.online.pack(&shape);
            let mut tm = trained_on(&train, &shape, &p_off, 10, 5 + i as u64);
            let frozen_acc = tm.accuracy(&online, &p_off);
            let mut rng = Xoshiro256::new(50 + i as u64);
            let mut rands = StepRands::draw(&mut rng, &shape);
            for _ in 0..8 {
                unlabelled_pass(
                    &mut tm,
                    &online,
                    &p_off,
                    &p_on,
                    PseudoLabelPolicy { min_margin: 2 },
                    &mut rng,
                    &mut rands,
                )
                .unwrap();
            }
            gain += tm.accuracy(&online, &p_off) - frozen_acc;
        }
        gain /= n as f64;
        assert!(gain > 0.0, "unlabelled learning mean gain {gain:.3}");
    }

    #[test]
    fn detector_flags_unseen_class_more_than_known() {
        // Train on two prototype classes of a 3-class synthetic dataset;
        // rows of the withheld prototype must be flagged as unseen far
        // more often than rows of the known classes. (On iris under
        // binary encoding, withheld-setosa rows alias into versicolor
        // clauses — the synthetic task isolates the mechanism.)
        let shape = TmShape { classes: 3, max_clauses: 8, features: 16, states: 100 };
        let mut params = TmParams::paper_offline(&shape);
        params.s = 3.0; // specific clauses -> crisp confidence signal
        params.active_classes = 2;
        let d = crate::data::synthetic::prototype_dataset(3, 60, 16, 0.05, 9).unwrap();
        let known_train = ClassFilter::removing(2).apply(&d.truncate(120));
        let train = known_train.pack(&shape);
        let mut tm = trained_on(&train, &shape, &params, 20, 7);
        let det = UnseenClassDetector { min_best_sum: 1 };
        let tail = d.subset(&(120..180).collect::<Vec<_>>());
        let unseen_rows = ClassFilter::removing(0)
            .apply(&ClassFilter::removing(1).apply(&tail))
            .pack(&shape);
        let known_rows = ClassFilter::removing(2).apply(&tail).pack(&shape);
        assert!(!unseen_rows.is_empty() && !known_rows.is_empty());
        let unseen_rate = det.flag_rate(&mut tm, &unseen_rows, &params);
        let known_rate = det.flag_rate(&mut tm, &known_rows, &params);
        assert!(
            unseen_rate > known_rate + 0.2,
            "unseen {unseen_rate:.2} vs known {known_rate:.2}"
        );
    }
}
