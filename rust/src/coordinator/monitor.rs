//! Continuous accuracy monitoring + retrain trigger — the paper's §5.3.2
//! and §7 future-work items: "continuous accuracy analysis (every N cycles
//! test the accuracy with a single piece of offline training data,
//! maintaining a cumulative average) can be used to detect faults and
//! trigger system retraining/resource re-provisioning."
//!
//! [`AccuracyMonitor`] keeps an exponentially-weighted accuracy estimate
//! from single-datapoint spot checks; [`RetrainPolicy`] decides when to
//! retrain and whether to enable over-provisioned clauses while doing so
//! (§5.3.2: "additional clauses can be enabled for this retraining to
//! further mitigate the effect of faulty TAs").

use crate::tm::bitplane::{BitPlanes, PlaneBatch};
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rescore::{RescoreCache, RescoreStats};
use crate::tm::rng::Xoshiro256;
use crate::tm::train_planes::{train_rows_seq, TrainScratch};
use anyhow::{ensure, Result};

/// Cumulative (EWMA) accuracy estimate from spot checks.
#[derive(Debug, Clone)]
pub struct AccuracyMonitor {
    /// Smoothing factor in (0, 1]; small = long memory.
    pub alpha: f64,
    estimate: f64,
    samples: u64,
}

impl AccuracyMonitor {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha) && alpha > 0.0);
        AccuracyMonitor { alpha, estimate: 1.0, samples: 0 }
    }

    /// Record one spot check (prediction correct or not).
    pub fn record(&mut self, correct: bool) {
        let x = if correct { 1.0 } else { 0.0 };
        if self.samples == 0 {
            self.estimate = x;
        } else {
            self.estimate = (1.0 - self.alpha) * self.estimate + self.alpha * x;
        }
        self.samples += 1;
    }

    pub fn estimate(&self) -> f64 {
        self.estimate
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// When to retrain and with what resources.
#[derive(Debug, Clone)]
pub struct RetrainPolicy {
    /// Trigger when the monitored estimate falls below this.
    pub threshold: f64,
    /// Minimum spot checks before the trigger can fire.
    pub warmup: u64,
    /// Clauses to activate during retraining (over-provisioning reserve).
    pub retrain_clauses: usize,
    /// Offline epochs for the on-chip retrain.
    pub retrain_epochs: usize,
}

/// Outcome of a monitored run segment.
#[derive(Debug, Clone)]
pub struct MonitorOutcome {
    pub triggered: bool,
    pub estimate_at_trigger: f64,
    pub spot_checks: u64,
    pub accuracy_after: f64,
}

/// Run spot checks over a stream of labelled datapoints; on trigger,
/// retrain on-chip with the policy's resources and report the result.
pub fn monitor_and_retrain(
    tm: &mut MultiTm,
    params: &mut TmParams,
    monitor: &mut AccuracyMonitor,
    policy: &RetrainPolicy,
    spot_stream: &[(Input, usize)],
    retrain_data: &[(Input, usize)],
    eval_data: &[(Input, usize)],
    seed: u64,
) -> Result<MonitorOutcome> {
    let mut triggered = false;
    let mut estimate_at_trigger = f64::NAN;
    for (x, y) in spot_stream {
        let pred = tm.predict(x, params);
        monitor.record(pred == *y);
        if !triggered
            && monitor.samples() >= policy.warmup
            && monitor.estimate() < policy.threshold
        {
            triggered = true;
            estimate_at_trigger = monitor.estimate();
            // On-chip retrain with over-provisioned clauses enabled,
            // through the lane-speculative engine: one transpose of the
            // retrain set, reused across every epoch — bit-identical to
            // the historical per-step refill + train_step_fast loop.
            params.active_clauses =
                policy.retrain_clauses.min(tm.shape().max_clauses);
            let shape = tm.shape().clone();
            let mut rng = Xoshiro256::new(seed);
            let mut scratch = TrainScratch::seeded(&mut rng, &shape);
            let retrain_planes = BitPlanes::from_labelled(&shape, retrain_data);
            for _ in 0..policy.retrain_epochs {
                train_rows_seq(tm, retrain_data, &retrain_planes, params, &mut rng, &mut scratch);
            }
        }
    }
    // Score the eval snapshot through the sample-sliced kernel
    // (transposed here, at the single point of use).
    let eval_planes = PlaneBatch::from_labelled(tm.shape(), eval_data);
    Ok(MonitorOutcome {
        triggered,
        estimate_at_trigger,
        spot_checks: monitor.samples(),
        accuracy_after: tm.accuracy_planes(&eval_planes, params),
    })
}

/// Trajectory of an interleaved train/re-score run
/// ([`online_rescore_run`]): the full-set accuracy after every re-score
/// interval, plus the incremental engine's work counters.
#[derive(Debug, Clone)]
pub struct RescoreTrace {
    /// Accuracy over the eval batch after each `rescore_every` steps.
    pub accuracies: Vec<f64>,
    /// The re-scorer's cumulative counters — `dirty_fraction()` is the
    /// fraction of clause visits that actually had to be re-ANDed.
    pub stats: RescoreStats,
}

/// The paper's headline interleaved loop as a driver: train online step
/// by step, re-scoring the whole cached eval batch after every
/// `rescore_every` steps through the incremental dirty-clause engine
/// ([`RescoreCache`]). Each point of the returned trajectory is
/// **bit-identical** to what a cold `accuracy_planes` pass at the same
/// step would report — the engine only skips clauses whose TA actions
/// did not flip since the previous re-score, which is what makes a
/// dense monitoring cadence (`rescore_every = 1`) affordable at all
/// (see EXPERIMENTS.md §Perf and the perf_table online-monitor row).
pub fn online_rescore_run(
    tm: &mut MultiTm,
    params: &TmParams,
    train: &[(Input, usize)],
    eval: &PlaneBatch,
    rescore_every: usize,
    seed: u64,
) -> Result<RescoreTrace> {
    ensure!(rescore_every > 0, "rescore_every must be positive");
    let shape = tm.shape().clone();
    let mut rng = Xoshiro256::new(seed);
    let mut scratch = TrainScratch::seeded(&mut rng, &shape);
    let mut cache = RescoreCache::new();
    let mut accuracies = Vec::new();
    // Each re-score interval is one lane-speculative run: same refill
    // order as the historical per-step loop (bit-identical trajectory),
    // clause evaluation amortized across the interval's samples. The
    // tail chunk (shorter than an interval) trains but does not score,
    // exactly like the per-step `(i + 1) % rescore_every` gate.
    for chunk in train.chunks(rescore_every) {
        let planes = BitPlanes::from_labelled(&shape, chunk);
        train_rows_seq(tm, chunk, &planes, params, &mut rng, &mut scratch);
        if chunk.len() == rescore_every {
            accuracies.push(cache.accuracy(tm, eval, params));
        }
    }
    Ok(RescoreTrace { accuracies, stats: cache.stats() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::{BlockPlan, SetAllocation};
    use crate::data::iris;
    use crate::tm::engine::train_step_fast;
    use crate::tm::fault::{Fault, FaultMap};
    use crate::tm::params::TmShape;
    use crate::tm::rng::StepRands;

    #[test]
    fn ewma_tracks_accuracy() {
        let mut m = AccuracyMonitor::new(0.2);
        for _ in 0..50 {
            m.record(true);
        }
        assert!(m.estimate() > 0.99);
        for _ in 0..50 {
            m.record(false);
        }
        assert!(m.estimate() < 0.05);
        assert_eq!(m.samples(), 100);
    }

    #[test]
    fn fault_burst_triggers_retrain_and_recovers() {
        let shape = TmShape::iris();
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 11).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.pack(&shape);
        let eval = sets.validation.pack(&shape);

        // Train with a clause reserve: only 12 of 16 active.
        let mut params = TmParams::paper_offline(&shape);
        params.active_clauses = 12;
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(2);
        let mut rands = StepRands::draw(&mut rng, &shape);
        for _ in 0..10 {
            for (x, y) in &train {
                rands.refill(&mut rng, &shape);
                train_step_fast(&mut tm, x, *y, &params, &rands);
            }
        }
        let acc_before = tm.accuracy(&eval, &params);
        assert!(acc_before > 0.6);

        // Fault burst that kills 10 of the 12 active clauses per class:
        // stuck-at-1 on a complement pair (x0 and ¬x0) makes a clause
        // unsatisfiable — the clause-output-level fault mode §7 proposes
        // studying.
        let mut map = FaultMap::none(&shape);
        for c in 0..shape.classes {
            for j in 0..10 {
                map.set(c, j, 0, Fault::StuckAt1);
                map.set(c, j, shape.features, Fault::StuckAt1);
            }
        }
        tm.set_fault_map(map);
        let mut monitor = AccuracyMonitor::new(0.15);
        let policy = RetrainPolicy {
            threshold: 0.62,
            warmup: 10,
            retrain_clauses: 16, // enable the over-provisioned reserve
            retrain_epochs: 20,
        };
        let spot: Vec<_> = train.iter().cycle().take(120).cloned().collect();
        let out = monitor_and_retrain(
            &mut tm,
            &mut params,
            &mut monitor,
            &policy,
            &spot,
            &train,
            &eval,
            77,
        )
        .unwrap();
        assert!(out.triggered, "the monitor must detect the fault burst");
        assert!(out.estimate_at_trigger < 0.62);
        assert_eq!(params.active_clauses, 16, "reserve clauses enabled");
        let faulted_untreated = {
            // Control: same faults, no retrain.
            let mut tm2 = MultiTm::new(&shape).unwrap();
            let mut rng2 = Xoshiro256::new(2);
            let mut r2 = StepRands::draw(&mut rng2, &shape);
            let mut p2 = TmParams::paper_offline(&shape);
            p2.active_clauses = 12;
            for _ in 0..10 {
                for (x, y) in &train {
                    r2.refill(&mut rng2, &shape);
                    train_step_fast(&mut tm2, x, *y, &p2, &r2);
                }
            }
            let mut map2 = FaultMap::none(&shape);
            for c in 0..shape.classes {
                for j in 0..10 {
                    map2.set(c, j, 0, Fault::StuckAt1);
                    map2.set(c, j, shape.features, Fault::StuckAt1);
                }
            }
            tm2.set_fault_map(map2);
            tm2.accuracy(&eval, &p2)
        };
        assert!(
            out.accuracy_after > faulted_untreated + 0.05,
            "retrain {:.3} must beat untreated {:.3}",
            out.accuracy_after,
            faulted_untreated
        );
    }

    /// The interleaved driver's trajectory is bit-identical to running
    /// the same schedule with a cold full-set re-score at every point.
    #[test]
    fn online_rescore_run_matches_cold_trajectory() {
        let shape = TmShape::iris();
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 13).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.pack(&shape);
        let eval = PlaneBatch::from_labelled(&shape, &sets.validation.pack(&shape));
        let params = TmParams::paper_offline(&shape);

        let mut incremental = MultiTm::new(&shape).unwrap();
        let stream: Vec<_> = train.iter().cycle().take(90).cloned().collect();
        let trace =
            online_rescore_run(&mut incremental, &params, &stream, &eval, 3, 0xAB).unwrap();
        assert_eq!(trace.accuracies.len(), 30);

        // Cold oracle: identical schedule, cold accuracy_planes per point.
        let mut cold = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(0xAB);
        let mut rands = StepRands::draw(&mut rng, &shape);
        let mut cold_curve = Vec::new();
        for (i, (x, y)) in stream.iter().enumerate() {
            rands.refill(&mut rng, &shape);
            train_step_fast(&mut cold, x, *y, &params, &rands);
            if (i + 1) % 3 == 0 {
                cold_curve.push(cold.accuracy_planes(&eval, &params));
            }
        }
        assert_eq!(trace.accuracies, cold_curve, "bit-identical trajectories");
        // Offline training flips actions while it learns, but never all
        // 48 clauses between every pair of points.
        let f = trace.stats.dirty_fraction();
        assert!(f < 1.0, "dirty fraction {f}");
        assert!(trace.stats.clean_clauses > 0);
        assert!(online_rescore_run(&mut cold, &params, &stream, &eval, 0, 1).is_err());
    }

    #[test]
    fn healthy_machine_never_triggers() {
        let shape = TmShape::iris();
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 11).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        let train = sets.offline.pack(&shape);
        let mut params = TmParams::paper_offline(&shape);
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(4);
        let mut rands = StepRands::draw(&mut rng, &shape);
        for _ in 0..10 {
            for (x, y) in &train {
                rands.refill(&mut rng, &shape);
                train_step_fast(&mut tm, x, *y, &params, &rands);
            }
        }
        let mut monitor = AccuracyMonitor::new(0.1);
        let policy = RetrainPolicy {
            threshold: 0.5,
            warmup: 10,
            retrain_clauses: 16,
            retrain_epochs: 1,
        };
        let spot: Vec<_> = train.iter().cycle().take(100).cloned().collect();
        let out = monitor_and_retrain(
            &mut tm,
            &mut params,
            &mut monitor,
            &policy,
            &spot,
            &train,
            &train,
            9,
        )
        .unwrap();
        assert!(!out.triggered);
        assert_eq!(out.spot_checks, 100);
    }
}
