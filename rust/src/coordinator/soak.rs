//! Deterministic replay/soak driver for the serving layer.
//!
//! Builds a warm-trained machine, generates a seeded Poisson-ish arrival
//! trace off the modular online input interface (ROM source → geometric
//! gaps, no wall clock), drives it through the sharded micro-batching
//! server, and cross-checks **every** response bit-identically against
//! the scalar [`ScalarOracle`] fed the same sequence. Because every
//! moving part is deterministic — trace generation, batching decisions,
//! the sequenced replica update log — a soak either agrees exactly or
//! has found a real ordering/replication bug; there is no tolerance
//! band.
//!
//! [`run_chaos_soak`] turns the same differential into a fault drill: a
//! seeded [`ChaosPlan`] kills, stalls and checkpoint-corrupts shards
//! mid-trace (optionally mixing malformed requests into the stream),
//! and the report asserts that the *recovered* server still matches the
//! never-failed oracle bit-for-bit — responses, final replica states,
//! and exact accounting of shed and quarantined requests.

use crate::data::blocks::{BlockPlan, SetAllocation};
use crate::data::filter::ClassFilter;
use crate::data::iris;
use crate::data::online::{arrival_trace, RomSource, TraceConfig};
use crate::hub::{HubConfig, HubError, ModelHandle, ModelHub, SingleModel};
use crate::net::{run_sim, seeded_scripts, NetConfig, NetStats, Outcome, ScriptConfig};
use crate::serve::{
    run_trace, snapshot_bytes, BatcherConfig, ChaosPlan, ChaosSpec, DriveStats, NetChaosPlan,
    NetChaosSpec, PendingRequest, RecoveryStats, ScalarOracle, ServeBackend, ServeConfig,
    ServeEvent, ShardServer, ShardStats,
};
use crate::store::{
    Disk, FaultDisk, FaultKind, FaultPlan, RealDisk, RecoveryReport, Store, StoreConfig,
    StoreError,
};
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::Xoshiro256;
use crate::tm::update::{ShardUpdate, UpdateKind};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Soak-run configuration (iris shape, paper-offline params).
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Shard replicas in the server under test.
    pub shards: usize,
    /// Arrival-trace length (requests + labelled updates).
    pub events: usize,
    /// Micro-batch lane cap, 1..=64.
    pub max_batch: usize,
    /// Flush deadline in virtual ticks.
    pub latency_budget: u64,
    /// Fraction of arrivals that carry a label (online updates).
    pub labelled_fraction: f32,
    /// Mean inter-arrival gap in ticks (0 = a single burst).
    pub mean_gap: f64,
    /// Master seed: warm-up training, trace generation and the replica
    /// update log all derive from it.
    pub seed: u64,
    /// Offline epochs to warm-train the served machine first, so
    /// predictions are non-trivial.
    pub warmup_epochs: usize,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            shards: 2,
            events: 1000,
            max_batch: 64,
            latency_budget: 8,
            labelled_fraction: 0.2,
            mean_gap: 1.0,
            seed: 42,
            warmup_epochs: 4,
        }
    }
}

/// What one soak run produced and whether it agreed with the oracle.
#[derive(Debug, Clone)]
pub struct SoakReport {
    /// Driver counters (flush breakdown, achieved batch width).
    pub drive: DriveStats,
    /// Server responses, sorted by request id.
    pub responses: Vec<(u64, usize)>,
    /// Per-shard work counters.
    pub shards: Vec<ShardStats>,
    /// Id-matched differences vs the scalar oracle: wrong predictions
    /// plus rows present on only one side, each counted once.
    pub mismatches: usize,
    /// Wall-clock seconds of the server arm (drive + join), for the
    /// throughput line; never used in any decision.
    pub wall_s: f64,
}

impl SoakReport {
    /// Bit-identical agreement with the scalar oracle.
    pub fn agrees(&self) -> bool {
        self.mismatches == 0
    }

    /// Served inference samples per wall-clock second.
    pub fn samples_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.responses.len() as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Chaos-soak configuration: a base soak plus the fault schedule's
/// shape and the server's fault policy.
#[derive(Debug, Clone)]
pub struct ChaosSoakConfig {
    pub soak: SoakConfig,
    /// Seed for [`ChaosPlan::seeded`] — independent of the trace seed,
    /// so one trace can be drilled under many schedules.
    pub chaos_seed: u64,
    pub kills: usize,
    pub stalls: usize,
    pub corrupts: usize,
    /// Replace every Nth inference request's input with one packed
    /// under the wrong shape (`0` = off) — exercises admission
    /// quarantine on both arms identically.
    pub malformed_every: usize,
    /// Server checkpoint cadence (updates per snapshot marker).
    pub checkpoint_every: u64,
    /// Operations a dead shard waits before recovery (0 = next op).
    pub recovery_lag: u64,
    /// Degraded-mode absorption cap per surviving shard.
    pub degraded_depth: u64,
}

impl Default for ChaosSoakConfig {
    fn default() -> Self {
        ChaosSoakConfig {
            soak: SoakConfig::default(),
            chaos_seed: 0xC4A0_5EED,
            kills: 2,
            stalls: 1,
            corrupts: 1,
            malformed_every: 97,
            checkpoint_every: 32,
            recovery_lag: 0,
            degraded_depth: u64::MAX,
        }
    }
}

/// What one chaos soak produced.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub drive: DriveStats,
    /// The generated fault schedule (for logs / reproduction).
    pub plan: ChaosPlan,
    /// Server responses, sorted by request id (shed ids absent).
    pub responses: Vec<(u64, usize)>,
    /// Request ids shed with an overload response, sorted.
    pub shed: Vec<u64>,
    pub recovery: RecoveryStats,
    /// Id-matched response differences vs the oracle, with shed ids
    /// excused (they are accounted, not lost).
    pub mismatches: usize,
    /// Every final shard replica is bit-identical to the oracle's
    /// machine after the full update log.
    pub replicas_match_oracle: bool,
    /// `responses + shed` covers the admitted request count exactly.
    pub accounting_exact: bool,
    pub wall_s: f64,
}

impl ChaosReport {
    /// Post-recovery bit-identity with the never-failed oracle run,
    /// with every non-response explicitly accounted.
    pub fn agrees(&self) -> bool {
        self.mismatches == 0 && self.replicas_match_oracle && self.accounting_exact
    }
}

/// Build the soak's event stream: warm-trained machine + packed trace.
fn soak_events(cfg: &SoakConfig, shape: &TmShape) -> Result<(MultiTm, Vec<ServeEvent>)> {
    let params = TmParams::paper_offline(shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, cfg.seed)?;
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper())?;
    let train = sets.offline.pack(shape);
    let mut tm = MultiTm::new(shape)?;
    let mut rng = Xoshiro256::new(cfg.seed);
    for _ in 0..cfg.warmup_epochs {
        tm.train_epoch(&train, &params, &mut rng);
    }
    let mut source = RomSource::new(iris::booleanised().clone(), ClassFilter::disabled())?;
    let trace = arrival_trace(
        &mut source,
        &TraceConfig {
            events: cfg.events,
            labelled_fraction: cfg.labelled_fraction,
            mean_gap: cfg.mean_gap,
            seed: cfg.seed ^ 0x7ACE_7ACE,
        },
    )?;
    let events = trace
        .events
        .iter()
        .map(|e| {
            let input = Input::pack(shape, &e.bits);
            match e.label {
                Some(label) => ServeEvent::Update {
                    at_tick: e.at_tick,
                    kind: UpdateKind::Learn { input, label },
                },
                None => ServeEvent::Infer { at_tick: e.at_tick, input },
            }
        })
        .collect();
    Ok((tm, events))
}

/// Id-matched diff over two id-sorted response lists: a wrong
/// prediction counts once, a row on only one side counts once — without
/// skewing every later comparison the way a positional zip would after
/// a single lost response. Oracle-only rows whose id is in `shed`
/// (sorted) are excused: the server declined them *explicitly*.
fn diff_responses(server: &[(u64, usize)], oracle: &[(u64, usize)], shed: &[u64]) -> usize {
    let is_shed = |id: u64| shed.binary_search(&id).is_ok();
    let (mut i, mut j, mut mismatches) = (0usize, 0usize, 0usize);
    while i < server.len() && j < oracle.len() {
        match server[i].0.cmp(&oracle[j].0) {
            std::cmp::Ordering::Equal => {
                if server[i].1 != oracle[j].1 {
                    mismatches += 1;
                }
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => {
                // Server-only row: the oracle answers everything, so
                // this is always wrong.
                mismatches += 1;
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                if !is_shed(oracle[j].0) {
                    mismatches += 1;
                }
                j += 1;
            }
        }
    }
    mismatches += server.len() - i;
    while j < oracle.len() {
        if !is_shed(oracle[j].0) {
            mismatches += 1;
        }
        j += 1;
    }
    mismatches
}

/// Run one soak: sharded server vs scalar oracle on the same trace.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let bcfg = BatcherConfig {
        max_batch: cfg.max_batch,
        latency_budget: cfg.latency_budget,
        expect_literals: Some(shape.literals()),
    };
    bcfg.validate()?;
    let (tm, events) = soak_events(cfg, &shape)?;

    let scfg = ServeConfig::new(cfg.shards, params.clone(), cfg.seed);
    let mut server = ShardServer::new(&tm, &scfg)?;
    let t0 = Instant::now();
    let drive = run_trace(&mut server, &events, &bcfg)?;
    let outcome = server.finish()?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut oracle = ScalarOracle::new(tm, params, cfg.seed);
    run_trace(&mut oracle, &events, &bcfg)?;
    let expected = oracle.into_responses();
    let mismatches = diff_responses(&outcome.responses, &expected, &[]);

    Ok(SoakReport {
        drive,
        responses: outcome.responses,
        shards: outcome.shards,
        mismatches,
        wall_s,
    })
}

/// Run one chaos soak: the same server-vs-oracle differential with a
/// seeded fault schedule driving kills, stalls, checkpoint corruption
/// and (optionally) malformed requests through the trace. The oracle
/// arm never fails; agreement therefore proves post-recovery
/// bit-identity, and the report carries the exact shed/quarantine
/// accounting.
pub fn run_chaos_soak(cfg: &ChaosSoakConfig) -> Result<ChaosReport> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let bcfg = BatcherConfig {
        max_batch: cfg.soak.max_batch,
        latency_budget: cfg.soak.latency_budget,
        expect_literals: Some(shape.literals()),
    };
    bcfg.validate()?;
    let (tm, mut events) = soak_events(&cfg.soak, &shape)?;

    // Malformed-request injection happens in the *trace*, upstream of
    // both arms, so the admission quarantine fires identically for the
    // server and the oracle.
    if cfg.malformed_every > 0 {
        let wrong_shape = TmShape { features: shape.features + 1, ..shape.clone() };
        let mut infer_idx = 0usize;
        for ev in events.iter_mut() {
            if let ServeEvent::Infer { input, .. } = ev {
                infer_idx += 1;
                if infer_idx % cfg.malformed_every == 0 {
                    *input = Input::pack(&wrong_shape, &vec![false; wrong_shape.features]);
                }
            }
        }
    }

    let total_updates =
        events.iter().filter(|e| matches!(e, ServeEvent::Update { .. })).count() as u64;
    let spec = ChaosSpec { kills: cfg.kills, stalls: cfg.stalls, corrupts: cfg.corrupts };
    let plan = ChaosPlan::seeded(cfg.chaos_seed, cfg.soak.shards, total_updates, &spec);

    let mut scfg = ServeConfig::new(cfg.soak.shards, params.clone(), cfg.soak.seed);
    scfg.fault.checkpoint_every = cfg.checkpoint_every;
    scfg.fault.recovery_lag = cfg.recovery_lag;
    scfg.fault.degraded_depth = cfg.degraded_depth;
    let mut server = ShardServer::with_chaos(&tm, &scfg, plan.clone())?;
    let t0 = Instant::now();
    let drive = run_trace(&mut server, &events, &bcfg)?;
    let outcome = server.finish()?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut oracle = ScalarOracle::new(tm, params, cfg.soak.seed);
    let oracle_drive = run_trace(&mut oracle, &events, &bcfg)?;
    let oracle_digest = oracle.machine().state_digest();
    let expected = oracle.into_responses();

    let mismatches = diff_responses(&outcome.responses, &expected, &outcome.shed);
    let replicas_match_oracle = !outcome.replicas.is_empty()
        && outcome.replicas.iter().all(|r| r.state_digest() == oracle_digest);
    // Both arms must have seen the same stream (quarantine included),
    // and every admitted request must be either answered or shed.
    let accounting_exact = drive == oracle_drive
        && (outcome.responses.len() + outcome.shed.len()) as u64 == drive.infer_requests
        && outcome.recovery.shed_requests == outcome.shed.len() as u64;

    Ok(ChaosReport {
        drive,
        plan,
        responses: outcome.responses,
        shed: outcome.shed,
        recovery: outcome.recovery,
        mismatches,
        replicas_match_oracle,
        accounting_exact,
        wall_s,
    })
}

/// Network-soak configuration: scripted clients with connection-level
/// chaos against the full front end, optionally with shard faults
/// layered underneath.
#[derive(Debug, Clone)]
pub struct NetSoakConfig {
    pub clients: usize,
    pub requests_per_client: u64,
    /// Fraction of requests that are `learn` frames.
    pub labelled_fraction: f32,
    /// Deadline budget stamped on every infer request.
    pub ttl: Option<u64>,
    /// Master seed: served machine, client scripts and update rands.
    pub seed: u64,
    /// Seed for the connection-fault schedule, independent of `seed` so
    /// one workload can be drilled under many schedules.
    pub net_chaos_seed: u64,
    pub spec: NetChaosSpec,
    pub shards: usize,
    pub max_batch: usize,
    pub latency_budget: u64,
    /// Per-session frame-debt cap (slow-client shed threshold).
    pub write_buffer_cap: u64,
    /// Global frame-debt cap (admission threshold).
    pub max_in_flight: u64,
    /// Optional shard-fault schedule (kills/stalls/corruptions) under
    /// the connection chaos; the oracle arm still never fails.
    pub shard_spec: Option<ChaosSpec>,
    pub shard_chaos_seed: u64,
    pub checkpoint_every: u64,
}

impl Default for NetSoakConfig {
    fn default() -> Self {
        NetSoakConfig {
            clients: 8,
            requests_per_client: 40,
            labelled_fraction: 0.25,
            ttl: Some(3),
            seed: 42,
            net_chaos_seed: 0x0005_EED5,
            spec: NetChaosSpec::full_matrix(),
            shards: 2,
            max_batch: 16,
            latency_budget: 4,
            write_buffer_cap: 8,
            max_in_flight: 256,
            shard_spec: None,
            shard_chaos_seed: 0xC4A0_5EED,
            checkpoint_every: 16,
        }
    }
}

/// What one network soak produced, with the cross-arm verdicts.
#[derive(Debug, Clone)]
pub struct NetSoakReport {
    /// Front-end accounting over the sharded server.
    pub server: NetStats,
    /// Front-end accounting over the scalar oracle.
    pub oracle: NetStats,
    /// The generated connection-fault schedule.
    pub plan: NetChaosPlan,
    /// Per-request outcome disagreements between the arms, after
    /// excusing explicit server-side overload sheds.
    pub outcome_mismatches: usize,
    /// Requests the degraded server shed with a typed overload answer
    /// where the never-failing oracle predicted.
    pub excused_server_shed: usize,
    /// All stats equal across arms (production-side counters excluded
    /// exactly when shard faults make them legitimately diverge).
    pub stats_match: bool,
    /// Every server replica's final state digest equals the oracle's.
    pub replicas_match: bool,
    /// Per-arm exactly-once identity: every admitted infer is answered,
    /// expired or explicitly shed — nothing lost, nothing doubled.
    pub accounting_exact: bool,
    pub wall_s: f64,
}

impl NetSoakReport {
    /// Bit-identity with the oracle arm plus exact accounting.
    pub fn agrees(&self) -> bool {
        self.outcome_mismatches == 0
            && self.stats_match
            && self.replicas_match
            && self.accounting_exact
    }
}

/// Per-request outcome diff: `(mismatches, excused server sheds)`. The
/// oracle arm never sheds server-side, so a server `ServerShed` against
/// an oracle prediction is accounted, not lost.
fn diff_outcomes(
    server: &BTreeMap<(usize, u64), Outcome>,
    oracle: &BTreeMap<(usize, u64), Outcome>,
) -> (usize, usize) {
    let mut mismatches = 0usize;
    let mut excused = 0usize;
    for (key, so) in server {
        match oracle.get(key) {
            Some(oo) if so == oo => {}
            Some(_) if matches!(so, Outcome::ServerShed) => excused += 1,
            _ => mismatches += 1,
        }
    }
    for key in oracle.keys() {
        if !server.contains_key(key) {
            mismatches += 1;
        }
    }
    (mismatches, excused)
}

/// Run one network chaos soak: identical scripted clients (torn frames,
/// half-open peers, disconnects, slow-loris readers, floods — all on
/// the virtual clock) drive two copies of the front end, one over the
/// sharded server and one over the scalar oracle. Because admission,
/// shedding and deadline decisions are pure functions of the scripts,
/// the arms must agree on *every* per-request outcome and counter; any
/// divergence is a real serving bug, not noise.
pub fn run_net_soak(cfg: &NetSoakConfig) -> Result<NetSoakReport> {
    let shape = TmShape::iris();
    let params = TmParams::paper_online(&shape);
    let mut rng = Xoshiro256::new(cfg.seed);
    let tm = crate::testkit::gen::machine(&mut rng, &shape);

    let plan =
        NetChaosPlan::seeded(cfg.net_chaos_seed, cfg.clients, cfg.requests_per_client, &cfg.spec);
    let script_cfg = ScriptConfig {
        clients: cfg.clients,
        requests_per_client: cfg.requests_per_client,
        labelled_fraction: cfg.labelled_fraction,
        features: shape.features,
        classes: shape.classes,
        ttl: cfg.ttl,
        // The net soak stays on v1 deliberately: it pins the legacy
        // single-model wire surface through the hub-era front end.
        hello_version: 1,
        model: None,
    };
    let scripts = seeded_scripts(cfg.seed ^ 0x00AD_BEEF, &script_cfg, &plan);
    let ncfg = NetConfig {
        batch: BatcherConfig {
            max_batch: cfg.max_batch,
            latency_budget: cfg.latency_budget,
            expect_literals: None,
        },
        max_in_flight: cfg.max_in_flight,
        write_buffer_cap: cfg.write_buffer_cap,
        ..Default::default()
    };

    let mut scfg = ServeConfig::new(cfg.shards, params.clone(), cfg.seed);
    scfg.fault.checkpoint_every = cfg.checkpoint_every;
    let server = match &cfg.shard_spec {
        Some(spec) => {
            let total = cfg.clients as u64 * cfg.requests_per_client;
            let shard_plan = ChaosPlan::seeded(cfg.shard_chaos_seed, cfg.shards, total, spec);
            ShardServer::with_chaos(&tm, &scfg, shard_plan)?
        }
        None => ShardServer::new(&tm, &scfg)?,
    };
    let t0 = Instant::now();
    let (srep, _stransport) = run_sim(SingleModel(server), scripts.clone(), &shape, ncfg.clone())?;
    let wall_s = t0.elapsed().as_secs_f64();

    let oracle = ScalarOracle::new(tm, params, cfg.seed);
    let (orep, _otransport) = run_sim(SingleModel(oracle), scripts, &shape, ncfg)?;

    let (outcome_mismatches, excused_server_shed) = diff_outcomes(&srep.outcomes, &orep.outcomes);
    let oracle_digest = orep.replicas.first().map(MultiTm::state_digest);
    let replicas_match = !srep.replicas.is_empty()
        && srep.replicas.iter().all(|r| Some(r.state_digest()) == oracle_digest);

    // Production-side counters (preds, server sheds) legitimately
    // diverge when shard faults shed work; every control-side counter
    // must match exactly.
    let mut s_norm = srep.stats;
    let mut o_norm = orep.stats;
    s_norm.preds = 0;
    s_norm.server_shed = 0;
    o_norm.preds = 0;
    o_norm.server_shed = 0;
    let stats_match = s_norm == o_norm && orep.stats.server_shed == 0;
    let exact = |st: &NetStats| st.infers == st.preds + st.deadline_expired + st.server_shed;
    let accounting_exact = exact(&srep.stats)
        && exact(&orep.stats)
        && excused_server_shed as u64 == srep.stats.server_shed;

    Ok(NetSoakReport {
        server: srep.stats,
        oracle: orep.stats,
        plan,
        outcome_mismatches,
        excused_server_shed,
        stats_match,
        replicas_match,
        accounting_exact,
        wall_s,
    })
}

/// Multi-tenant hub-soak configuration: N tenants with independent
/// warm machines and traces, interleaved round-robin against one
/// shared [`ModelHub`] under a memory budget, with evictions forced
/// mid-trace.
#[derive(Debug, Clone)]
pub struct HubSoakConfig {
    /// Tenant models sharing the hub (the acceptance floor is 4).
    pub tenants: usize,
    /// Arrival-trace length per tenant.
    pub events_per_tenant: usize,
    /// Trace segments per tenant: tenants interleave on the hub one
    /// segment at a time, so residency genuinely churns mid-trace.
    pub rounds: usize,
    pub max_batch: usize,
    pub latency_budget: u64,
    pub labelled_fraction: f32,
    pub mean_gap: f64,
    /// Master seed; tenant `t` derives everything from
    /// `seed ^ (t+1)·φ64`, so traces and machines are independent.
    pub seed: u64,
    pub warmup_epochs: usize,
    /// Hub memory budget in whole model replicas (`0` = unlimited);
    /// below `tenants` it forces LRU eviction under load.
    pub budget_models: usize,
    /// Hub checkpoint-refresh cadence (bounds rehydration replay).
    pub checkpoint_every: u64,
    /// Force-evict tenant `t` after round `r` when
    /// `(r + t) % evict_period == 0` (`0` = rely on the budget alone).
    pub evict_period: usize,
    /// Explicit tenant model names (the CLI's repeatable
    /// `--model NAME=SPEC`); tenants beyond the list get `tenant-{t}`.
    pub tenant_names: Vec<String>,
}

impl HubSoakConfig {
    /// The hub model name tenant `t` registers and serves under.
    pub fn tenant_name(&self, t: usize) -> String {
        self.tenant_names.get(t).cloned().unwrap_or_else(|| format!("tenant-{t}"))
    }
}

impl Default for HubSoakConfig {
    fn default() -> Self {
        HubSoakConfig {
            tenants: 4,
            events_per_tenant: 200,
            rounds: 4,
            max_batch: 16,
            latency_budget: 6,
            labelled_fraction: 0.25,
            mean_gap: 1.0,
            seed: 42,
            warmup_epochs: 2,
            budget_models: 2,
            checkpoint_every: 16,
            evict_period: 2,
            tenant_names: Vec::new(),
        }
    }
}

/// One tenant's verdict against its private scalar oracle.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    /// Responses the hub arm produced for this tenant.
    pub responses: usize,
    /// Id-matched response differences vs the tenant's oracle.
    pub mismatches: usize,
    /// Per-segment [`DriveStats`] equal across arms.
    pub stats_match: bool,
    /// Final hub replica digest equals the oracle machine's.
    pub digest_match: bool,
    pub evictions: u64,
    pub rehydrations: u64,
}

/// What one multi-tenant hub soak produced.
#[derive(Debug, Clone)]
pub struct HubSoakReport {
    pub tenants: Vec<TenantReport>,
    /// Shared bitplane-cache `(hits, misses)` across all tenants.
    pub plane_cache: (u64, u64),
    /// Resident model bytes at end of drive (must respect the budget).
    pub resident_bytes: usize,
    pub wall_s: f64,
}

impl HubSoakReport {
    /// Every tenant bit-identical to its oracle: responses, per-segment
    /// drive stats and final replica digest.
    pub fn agrees(&self) -> bool {
        self.tenants
            .iter()
            .all(|t| t.mismatches == 0 && t.stats_match && t.digest_match)
    }
}

/// Drives one tenant's flushed batches and sequenced updates into the
/// shared hub under that tenant's handle.
struct HubTenant<'a> {
    hub: &'a mut ModelHub,
    h: ModelHandle,
    out: &'a mut Vec<(u64, usize)>,
}

impl ServeBackend for HubTenant<'_> {
    fn update(&mut self, kind: UpdateKind) {
        self.hub.update(self.h, kind).expect("hub soak: update on a live model");
    }

    fn infer_batch(&mut self, batch: Vec<PendingRequest>) {
        let ids: Vec<u64> = batch.iter().map(|p| p.id).collect();
        let inputs: Vec<Input> = batch.into_iter().map(|p| p.input).collect();
        let classes =
            self.hub.infer(self.h, &inputs).expect("hub soak: infer on a live model");
        self.out.extend(ids.into_iter().zip(classes));
    }
}

/// [`run_trace`] assigns request ids `0..` per call; when a tenant's
/// trace is driven one segment at a time, later segments must not reuse
/// earlier ids. This shim rebases a segment's ids by the infer count of
/// everything before it — applied identically on both arms, so the
/// id-matched diff stays aligned.
struct OffsetIds<'a, B> {
    inner: &'a mut B,
    offset: u64,
}

impl<B: ServeBackend> ServeBackend for OffsetIds<'_, B> {
    fn update(&mut self, kind: UpdateKind) {
        self.inner.update(kind);
    }

    fn infer_batch(&mut self, mut batch: Vec<PendingRequest>) {
        for p in &mut batch {
            p.id += self.offset;
        }
        self.inner.infer_batch(batch);
    }
}

/// Segment `r` of `rounds` over a `len`-event trace.
fn segment(len: usize, rounds: usize, r: usize) -> (usize, usize) {
    (len * r / rounds, len * (r + 1) / rounds)
}

/// Run one multi-tenant hub soak. Each tenant gets an independent
/// warm-trained machine and arrival trace; all tenants interleave
/// round-robin on ONE shared [`ModelHub`] under a memory budget of
/// `budget_models` replicas, with forced evictions between segments —
/// so every tenant's model is evicted and transparently rehydrated
/// mid-trace. The oracle arm replays each tenant's identical segmented
/// trace through a private [`ScalarOracle`]; the report demands
/// bit-identical responses, per-segment drive stats and final replica
/// digests per tenant. Agreement proves the hub's eviction/rehydration
/// machinery is invisible to tenants — the tentpole contract.
pub fn run_hub_soak(cfg: &HubSoakConfig) -> Result<HubSoakReport> {
    anyhow::ensure!(cfg.tenants >= 1, "hub soak: need at least one tenant");
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let bcfg = BatcherConfig {
        max_batch: cfg.max_batch,
        latency_budget: cfg.latency_budget,
        expect_literals: Some(shape.literals()),
    };
    bcfg.validate()?;
    let rounds = cfg.rounds.max(1);

    // Independent per-tenant seed → independent warm machine + trace.
    let mut tenants = Vec::with_capacity(cfg.tenants);
    for t in 0..cfg.tenants {
        let tseed = cfg.seed ^ ((t as u64) + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let tcfg = SoakConfig {
            shards: 1,
            events: cfg.events_per_tenant,
            max_batch: cfg.max_batch,
            latency_budget: cfg.latency_budget,
            labelled_fraction: cfg.labelled_fraction,
            mean_gap: cfg.mean_gap,
            seed: tseed,
            warmup_epochs: cfg.warmup_epochs,
        };
        let (tm, events) = soak_events(&tcfg, &shape)?;
        tenants.push((tseed, tm, events));
    }

    // The budget is denominated in whole replicas of the largest model.
    let replica_cost = tenants
        .iter()
        .map(|(_, tm, _)| snapshot_bytes(tm, &params, 0).len())
        .max()
        .unwrap_or(0);
    let mut hub = ModelHub::new(HubConfig {
        memory_budget: cfg.budget_models.saturating_mul(replica_cost),
        checkpoint_every: cfg.checkpoint_every,
        plane_cache_batches: 64,
    });
    let mut handles = Vec::with_capacity(cfg.tenants);
    for (t, (tseed, tm, _)) in tenants.iter().enumerate() {
        let name = cfg.tenant_name(t);
        let h = hub
            .create(&name, tm.clone(), params.clone(), *tseed)
            .map_err(|e| anyhow::anyhow!("hub soak: create {name}: {e}"))?;
        handles.push(h);
    }

    // Hub arm: tenants interleave one segment per round, forced
    // evictions between segments, LRU churn from the budget throughout.
    let t0 = Instant::now();
    let mut hub_responses: Vec<Vec<(u64, usize)>> = vec![Vec::new(); cfg.tenants];
    let mut hub_drives: Vec<Vec<DriveStats>> = vec![Vec::new(); cfg.tenants];
    let mut offsets = vec![0u64; cfg.tenants];
    for r in 0..rounds {
        for t in 0..cfg.tenants {
            let events = &tenants[t].2;
            let (lo, hi) = segment(events.len(), rounds, r);
            let seg = &events[lo..hi];
            let mut backend = HubTenant {
                hub: &mut hub,
                h: handles[t],
                out: &mut hub_responses[t],
            };
            let mut shim = OffsetIds { inner: &mut backend, offset: offsets[t] };
            hub_drives[t].push(run_trace(&mut shim, seg, &bcfg)?);
            offsets[t] +=
                seg.iter().filter(|e| matches!(e, ServeEvent::Infer { .. })).count() as u64;
            if cfg.evict_period > 0 && (r + t) % cfg.evict_period == 0 {
                hub.evict(handles[t])
                    .map_err(|e| anyhow::anyhow!("hub soak: forced evict tenant-{t}: {e}"))?;
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let resident_bytes = hub.resident_bytes();

    // Oracle arm + per-tenant verdicts.
    let mut reports = Vec::with_capacity(cfg.tenants);
    for t in 0..cfg.tenants {
        let (tseed, tm, events) = &tenants[t];
        let mut oracle = ScalarOracle::new(tm.clone(), params.clone(), *tseed);
        let mut oracle_drives = Vec::with_capacity(rounds);
        let mut offset = 0u64;
        for r in 0..rounds {
            let (lo, hi) = segment(events.len(), rounds, r);
            let seg = &events[lo..hi];
            let mut shim = OffsetIds { inner: &mut oracle, offset };
            oracle_drives.push(run_trace(&mut shim, seg, &bcfg)?);
            offset +=
                seg.iter().filter(|e| matches!(e, ServeEvent::Infer { .. })).count() as u64;
        }
        let oracle_digest = oracle.machine().state_digest();
        let expected = oracle.into_responses();
        let mut got = hub_responses[t].clone();
        got.sort_unstable_by_key(|&(id, _)| id);
        let (evictions, rehydrations) = hub.lifecycle(handles[t]);
        let digest = hub
            .digest(handles[t])
            .map_err(|e| anyhow::anyhow!("hub soak: digest tenant-{t}: {e}"))?;
        reports.push(TenantReport {
            name: cfg.tenant_name(t),
            responses: got.len(),
            mismatches: diff_responses(&got, &expected, &[]),
            stats_match: hub_drives[t] == oracle_drives,
            digest_match: digest == oracle_digest,
            evictions,
            rehydrations,
        });
    }

    Ok(HubSoakReport {
        tenants: reports,
        plane_cache: hub.plane_cache_stats(),
        resident_bytes,
        wall_s,
    })
}

/// Crash-restart soak over the durable hub (`ModelHub::open_durable` +
/// `crate::store`). Per-tenant traces are driven *directly* into the
/// hub (no batcher — every event is one hub call, so the crash point
/// maps one-to-one onto a durable write boundary), a seeded
/// [`FaultDisk`] kills the process-equivalent at the `c`-th durable
/// write, and the restarted hub must resume each tenant from its
/// durable seq and finish **bit-identical** to a never-crashed scalar
/// oracle: every answered inference equal, every final digest equal.
#[derive(Debug, Clone)]
pub struct RestartSoakConfig {
    /// Tenant models sharing the durable hub (acceptance floor: 2).
    pub tenants: usize,
    /// Arrival-trace length per tenant (updates + inferences).
    pub events_per_tenant: usize,
    /// Fraction of events that are labelled updates — kept high so the
    /// WAL sees enough appends for a dense crash sweep.
    pub labelled_fraction: f32,
    pub mean_gap: f64,
    /// Master seed; tenant `t` derives everything from
    /// `seed ^ (t+1)·φ64`, like the hub soak.
    pub seed: u64,
    pub warmup_epochs: usize,
    /// Durable checkpoint-refresh cadence per model.
    pub checkpoint_every: u64,
    /// Force-evict the tenant just driven after every N processed
    /// events (`0` = off) — evictions write through, so the sweep also
    /// crashes inside eviction publishes.
    pub evict_every: u64,
    /// WAL segment size; small enough that the sweep crosses rotations.
    pub segment_bytes: u64,
    /// Store root. [`run_restart_soak`] treats it as scratch (wiped,
    /// one subdirectory per crash point); [`run_restart_once`] operates
    /// on it in place — that is the CLI kill-and-relaunch drill.
    pub data_dir: PathBuf,
    /// Cap on swept crash points (`0` = every durable write boundary);
    /// capped sweeps sample evenly across the op range.
    pub max_crash_points: usize,
    /// Explicit tenant model names (CLI `--model NAME=SPEC`); tenants
    /// beyond the list get `tenant-{t}`.
    pub tenant_names: Vec<String>,
}

impl RestartSoakConfig {
    /// The hub model name tenant `t` registers and serves under.
    pub fn tenant_name(&self, t: usize) -> String {
        self.tenant_names.get(t).cloned().unwrap_or_else(|| format!("tenant-{t}"))
    }
}

impl Default for RestartSoakConfig {
    fn default() -> Self {
        RestartSoakConfig {
            tenants: 2,
            events_per_tenant: 120,
            labelled_fraction: 0.5,
            mean_gap: 1.0,
            seed: 42,
            warmup_epochs: 2,
            checkpoint_every: 8,
            evict_every: 13,
            segment_bytes: 16 * 1024,
            data_dir: std::env::temp_dir().join("tmfpga_restart_soak"),
            max_crash_points: 0,
            tenant_names: Vec::new(),
        }
    }
}

/// What one crash-restart sweep produced.
#[derive(Debug, Clone, Default)]
pub struct RestartSoakReport {
    /// Durable write boundaries in one clean run (the sweep domain).
    pub durable_ops: u64,
    /// Crash points actually swept.
    pub crash_points: u64,
    /// Sweep runs where the injected crash surfaced as a fail-stop.
    pub crashes_observed: u64,
    /// Answer or digest differences vs the never-crashed oracle, plus
    /// re-answered inferences that changed across the restart.
    pub divergences: u64,
    /// Inferences left unanswered by crash run + resume run combined.
    pub answer_gaps: u64,
    /// Torn WAL tails truncated across all restarts.
    pub torn_tails_truncated: u64,
    /// WAL records replayed into recovered models across all restarts.
    pub wal_records_replayed: u64,
    /// Models rebuilt from disk across all restarts.
    pub models_recovered: u64,
    pub wall_s: f64,
}

impl RestartSoakReport {
    /// Every injected crash surfaced, and every restart was
    /// bit-identical to the never-crashed oracle with full response
    /// coverage.
    pub fn agrees(&self) -> bool {
        self.crash_points > 0
            && self.crashes_observed == self.crash_points
            && self.divergences == 0
            && self.answer_gaps == 0
    }
}

/// What one [`run_restart_once`] pass (the CLI drill's unit) produced.
#[derive(Debug, Clone)]
pub struct RestartRun {
    /// The pass hit a storage fail-stop (injected or real) mid-trace.
    pub crashed: bool,
    /// Inferences answered by this pass.
    pub answered: u64,
    /// Answers (and, when the pass completed, digests) differing from
    /// the never-crashed oracle.
    pub divergences: u64,
    /// Recovery counters from the store open, when the open succeeded.
    pub recovery: Option<RecoveryReport>,
}

/// One tenant's deterministic ingredients: warm machine, trace, and the
/// prefix tables that map a durable resume seq back to a trace cursor.
struct TenantSetup {
    name: String,
    tseed: u64,
    machine: MultiTm,
    params: TmParams,
    events: Vec<RestartEvent>,
    /// Event index of the k-th (1-based) update — resume cursor for a
    /// model recovered at seq `k` is `update_at[k - 1] + 1`.
    update_at: Vec<usize>,
    /// Inferences among `events[..i]`, for `i in 0..len` — the answer
    /// slot of the inference at event `i`.
    infer_prefix: Vec<usize>,
    total_infers: usize,
}

enum RestartEvent {
    Update(UpdateKind),
    Infer(Input),
}

fn restart_setups(cfg: &RestartSoakConfig) -> Result<Vec<TenantSetup>> {
    anyhow::ensure!(cfg.tenants >= 1, "restart soak: need at least one tenant");
    let shape = TmShape::iris();
    let mut setups = Vec::with_capacity(cfg.tenants);
    for t in 0..cfg.tenants {
        let tseed = cfg.seed ^ ((t as u64) + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let tcfg = SoakConfig {
            shards: 1,
            events: cfg.events_per_tenant,
            max_batch: 16,
            latency_budget: 4,
            labelled_fraction: cfg.labelled_fraction,
            mean_gap: cfg.mean_gap,
            seed: tseed,
            warmup_epochs: cfg.warmup_epochs,
        };
        let (machine, trace) = soak_events(&tcfg, &shape)?;
        let events: Vec<RestartEvent> = trace
            .into_iter()
            .map(|e| match e {
                ServeEvent::Update { kind, .. } => RestartEvent::Update(kind),
                ServeEvent::Infer { input, .. } => RestartEvent::Infer(input),
            })
            .collect();
        let mut update_at = Vec::new();
        let mut infer_prefix = Vec::with_capacity(events.len());
        let mut infers = 0usize;
        for (i, e) in events.iter().enumerate() {
            infer_prefix.push(infers);
            match e {
                RestartEvent::Update(_) => update_at.push(i),
                RestartEvent::Infer(_) => infers += 1,
            }
        }
        setups.push(TenantSetup {
            name: cfg.tenant_name(t),
            tseed,
            machine,
            params: TmParams::paper_offline(&shape),
            events,
            update_at,
            infer_prefix,
            total_infers: infers,
        });
    }
    Ok(setups)
}

/// The never-crashed oracle: each tenant's trace applied to a private
/// scalar machine. Returns per-tenant answers (by inference index) and
/// final state digests.
fn restart_oracle(setups: &[TenantSetup]) -> (Vec<Vec<usize>>, Vec<u64>) {
    let mut answers = Vec::with_capacity(setups.len());
    let mut digests = Vec::with_capacity(setups.len());
    for s in setups {
        let mut machine = s.machine.clone();
        let mut scratch = None;
        let mut seq = 0u64;
        let mut ans = Vec::with_capacity(s.total_infers);
        for e in &s.events {
            match e {
                RestartEvent::Update(kind) => {
                    seq += 1;
                    let u = ShardUpdate { seq, kind: kind.clone() };
                    machine.apply_update_with(&u, &s.params, s.tseed, &mut scratch);
                }
                RestartEvent::Infer(input) => ans.push(machine.predict(input, &s.params)),
            }
        }
        answers.push(ans);
        digests.push(machine.state_digest());
    }
    (answers, digests)
}

/// The completed (non-crashed) half of a [`restart_pass`].
struct PassHub {
    hub: ModelHub,
    handles: Vec<ModelHandle>,
}

struct PassResult {
    crashed: bool,
    /// Present only when the pass drove every tenant's trace to its
    /// end.
    done: Option<PassHub>,
    /// Recovery counters from the store open (absent when the crash
    /// landed inside the open itself).
    recovery: Option<RecoveryReport>,
}

/// One process lifetime: open (recover) the store at `cfg.data_dir`,
/// resume every tenant at its durable seq, drive round-robin until the
/// traces finish or a storage fail-stop lands. `answers` carries each
/// tenant's per-inference responses across passes; an inference
/// re-answered after a restart must match what the crashed pass already
/// committed, else `divergences` is bumped.
fn restart_pass(
    disk: Box<dyn Disk>,
    cfg: &RestartSoakConfig,
    setups: &[TenantSetup],
    answers: &mut [Vec<Option<usize>>],
    divergences: &mut u64,
) -> Result<PassResult> {
    let store_cfg = StoreConfig { segment_bytes: cfg.segment_bytes, ..StoreConfig::default() };
    let (store, recovered) = match Store::open(disk, &cfg.data_dir, store_cfg) {
        Ok(v) => v,
        Err(StoreError::Crashed { .. }) | Err(StoreError::Poisoned) => {
            return Ok(PassResult { crashed: true, done: None, recovery: None });
        }
        Err(e) => return Err(e.into()),
    };
    let recovery = Some(*store.report());
    let crashed = |recovery: Option<RecoveryReport>| -> Result<PassResult> {
        Ok(PassResult { crashed: true, done: None, recovery })
    };
    let hub_cfg = HubConfig {
        memory_budget: 0,
        checkpoint_every: cfg.checkpoint_every,
        plane_cache_batches: 64,
    };
    let mut hub = ModelHub::open_durable(hub_cfg, store, recovered)
        .map_err(|e| anyhow::anyhow!("restart soak: open durable hub: {e}"))?;

    // Resume (or first-create) every tenant. A create that crashed
    // after its WAL append is already recovered by name; one that never
    // reached the log is re-created — both land on the identical
    // genesis because the warm machine is deterministic.
    let mut handles = Vec::with_capacity(setups.len());
    let mut cursors = Vec::with_capacity(setups.len());
    let mut next_seq = Vec::with_capacity(setups.len());
    for s in setups {
        let h = match hub.resolve(&s.name) {
            Some(h) => h,
            None => {
                match hub.create(&s.name, s.machine.clone(), s.params.clone(), s.tseed) {
                    Ok(h) => h,
                    Err(HubError::Storage { .. }) => return crashed(recovery),
                    Err(e) => anyhow::bail!("restart soak: create {}: {e}", s.name),
                }
            }
        };
        let seq = hub.model_seq(h).expect("restart soak: just resolved or created");
        anyhow::ensure!(
            (seq as usize) <= s.update_at.len(),
            "restart soak: {} recovered at seq {seq}, trace only has {} updates",
            s.name,
            s.update_at.len()
        );
        cursors.push(if seq == 0 { 0 } else { s.update_at[seq as usize - 1] + 1 });
        next_seq.push(seq);
        handles.push(h);
    }

    // Round-robin drive, one event per tenant per turn.
    let mut processed = 0u64;
    loop {
        let mut idle = true;
        for (t, s) in setups.iter().enumerate() {
            let i = cursors[t];
            if i >= s.events.len() {
                continue;
            }
            idle = false;
            match &s.events[i] {
                RestartEvent::Update(kind) => match hub.update(handles[t], kind.clone()) {
                    Ok(seq) => {
                        next_seq[t] += 1;
                        anyhow::ensure!(
                            seq == next_seq[t],
                            "restart soak: {} got seq {seq}, expected {}",
                            s.name,
                            next_seq[t]
                        );
                    }
                    Err(HubError::Storage { .. }) => return crashed(recovery),
                    Err(e) => anyhow::bail!("restart soak: update {}: {e}", s.name),
                },
                RestartEvent::Infer(input) => {
                    match hub.infer(handles[t], std::slice::from_ref(input)) {
                        Ok(classes) => {
                            let k = s.infer_prefix[i];
                            let got = classes[0];
                            match answers[t][k] {
                                Some(prev) if prev != got => *divergences += 1,
                                _ => answers[t][k] = Some(got),
                            }
                        }
                        Err(HubError::Storage { .. }) => return crashed(recovery),
                        Err(e) => anyhow::bail!("restart soak: infer {}: {e}", s.name),
                    }
                }
            }
            cursors[t] = i + 1;
            processed += 1;
            if cfg.evict_every > 0 && processed % cfg.evict_every == 0 {
                match hub.evict(handles[t]) {
                    Ok(()) => {}
                    Err(HubError::Storage { .. }) => return crashed(recovery),
                    Err(e) => anyhow::bail!("restart soak: evict {}: {e}", s.name),
                }
            }
        }
        if idle {
            break;
        }
    }
    Ok(PassResult { crashed: false, done: Some(PassHub { hub, handles }), recovery })
}

/// One pass over the persistent store at `cfg.data_dir` — the CLI
/// kill-and-relaunch drill's unit. With `crash_after = Some(n)` the
/// `n`-th durable write fails as a crash (the caller then exits the
/// process); with `None` the pass recovers whatever a previous process
/// left, drives the remaining trace, and verifies answers and final
/// digests against the never-crashed oracle.
pub fn run_restart_once(
    cfg: &RestartSoakConfig,
    crash_after: Option<u64>,
) -> Result<RestartRun> {
    let setups = restart_setups(cfg)?;
    let (oracle_answers, oracle_digests) = restart_oracle(&setups);
    let mut answers: Vec<Vec<Option<usize>>> =
        setups.iter().map(|s| vec![None; s.total_infers]).collect();
    let mut divergences = 0u64;
    let disk: Box<dyn Disk> = match crash_after {
        Some(n) => Box::new(FaultDisk::new(Some(FaultPlan {
            fail_at_op: n,
            kind: FaultKind::Crash,
        }))),
        None => Box::new(RealDisk),
    };
    let pass = restart_pass(disk, cfg, &setups, &mut answers, &mut divergences)?;
    let mut answered = 0u64;
    for (t, tenant_answers) in answers.iter().enumerate() {
        for (k, a) in tenant_answers.iter().enumerate() {
            if let Some(got) = a {
                answered += 1;
                if *got != oracle_answers[t][k] {
                    divergences += 1;
                }
            }
        }
    }
    if let Some(mut done) = pass.done {
        for t in 0..setups.len() {
            let digest = done
                .hub
                .digest(done.handles[t])
                .map_err(|e| anyhow::anyhow!("restart soak: digest {}: {e}", setups[t].name))?;
            if digest != oracle_digests[t] {
                divergences += 1;
            }
        }
        done.hub
            .sync_durable()
            .map_err(|e| anyhow::anyhow!("restart soak: final sync: {e}"))?;
    }
    Ok(RestartRun { crashed: pass.crashed, answered, divergences, recovery: pass.recovery })
}

/// The full seeded crash sweep: probe one clean run to count its
/// durable write boundaries, then for each crash point `c` run the
/// trace in a fresh subdirectory with the `c`-th durable write failing
/// as a crash, restart cleanly, and demand the resumed run is
/// bit-identical to the never-crashed oracle — answers, re-answers and
/// final digests, with every recovery counter aggregated.
pub fn run_restart_soak(cfg: &RestartSoakConfig) -> Result<RestartSoakReport> {
    let t0 = Instant::now();
    let setups = restart_setups(cfg)?;
    let (oracle_answers, oracle_digests) = restart_oracle(&setups);
    std::fs::remove_dir_all(&cfg.data_dir).ok();

    let verify = |answers: &[Vec<Option<usize>>],
                  done: &mut PassHub,
                  divergences: &mut u64,
                  gaps: &mut u64|
     -> Result<()> {
        for (t, s) in setups.iter().enumerate() {
            for k in 0..s.total_infers {
                match answers[t][k] {
                    Some(got) if got == oracle_answers[t][k] => {}
                    Some(_) => *divergences += 1,
                    None => *gaps += 1,
                }
            }
            let digest = done
                .hub
                .digest(done.handles[t])
                .map_err(|e| anyhow::anyhow!("restart soak: digest {}: {e}", s.name))?;
            if digest != oracle_digests[t] {
                *divergences += 1;
            }
        }
        Ok(())
    };

    // Probe: one clean run through a counting disk fixes the sweep
    // domain — the driver is deterministic, so every later run issues
    // the identical durable-write sequence.
    let mut report = RestartSoakReport::default();
    {
        let mut sub = cfg.clone();
        sub.data_dir = cfg.data_dir.join("probe");
        let fd = FaultDisk::new(None);
        let ops = fd.op_counter();
        let mut answers: Vec<Vec<Option<usize>>> =
            setups.iter().map(|s| vec![None; s.total_infers]).collect();
        let mut divergences = 0u64;
        let pass = restart_pass(Box::new(fd), &sub, &setups, &mut answers, &mut divergences)?;
        let mut done = pass
            .done
            .ok_or_else(|| anyhow::anyhow!("restart soak: probe run crashed without a fault"))?;
        let mut gaps = 0u64;
        verify(&answers, &mut done, &mut divergences, &mut gaps)?;
        anyhow::ensure!(
            divergences == 0 && gaps == 0,
            "restart soak: probe run diverged from the oracle without any fault \
             ({divergences} divergences, {gaps} gaps)"
        );
        report.durable_ops = ops.load(Ordering::SeqCst);
        std::fs::remove_dir_all(&sub.data_dir).ok();
    }
    anyhow::ensure!(report.durable_ops > 0, "restart soak: no durable writes to crash");

    // The sweep: every durable write boundary, or an even sample.
    let n = report.durable_ops;
    let step = if cfg.max_crash_points > 0 {
        (n / cfg.max_crash_points as u64).max(1)
    } else {
        1
    };
    let mut c = 1;
    while c <= n {
        let mut sub = cfg.clone();
        sub.data_dir = cfg.data_dir.join(format!("cp-{c:05}"));
        let mut answers: Vec<Vec<Option<usize>>> =
            setups.iter().map(|s| vec![None; s.total_infers]).collect();
        let mut divergences = 0u64;

        // Crash run: the c-th durable write fails, sticky.
        let disk = Box::new(FaultDisk::new(Some(FaultPlan {
            fail_at_op: c,
            kind: FaultKind::Crash,
        })));
        let pass = restart_pass(disk, &sub, &setups, &mut answers, &mut divergences)?;
        report.crash_points += 1;
        if pass.crashed {
            report.crashes_observed += 1;
        }

        // Restart run: clean disk, recover, resume, finish.
        let pass =
            restart_pass(Box::new(RealDisk), &sub, &setups, &mut answers, &mut divergences)?;
        anyhow::ensure!(!pass.crashed, "restart soak: clean restart at crash point {c} failed");
        let mut done = pass.done.expect("non-crashed pass carries its hub");
        if let Some(r) = pass.recovery {
            report.torn_tails_truncated += r.torn_tails_truncated;
            report.wal_records_replayed += r.wal_records_replayed;
            report.models_recovered += r.models_recovered;
        }
        let mut gaps = 0u64;
        verify(&answers, &mut done, &mut divergences, &mut gaps)?;
        report.divergences += divergences;
        report.answer_gaps += gaps;
        std::fs::remove_dir_all(&sub.data_dir).ok();
        c += step;
    }
    std::fs::remove_dir_all(&cfg.data_dir).ok();
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One quick end-to-end agreement check; the heavy differential
    /// matrix (shard counts × batch widths × fault injection) lives in
    /// `rust/tests/integration_serve.rs`.
    #[test]
    fn default_soak_agrees_with_oracle() {
        let cfg = SoakConfig { events: 300, warmup_epochs: 2, ..Default::default() };
        let rep = run_soak(&cfg).unwrap();
        assert!(rep.agrees(), "{} mismatches", rep.mismatches);
        assert!(rep.drive.infer_requests > 0 && rep.drive.updates > 0);
        assert_eq!(rep.responses.len() as u64, rep.drive.infer_requests);
        assert_eq!(rep.drive.width_sum, rep.drive.infer_requests);
        let width = rep.drive.mean_batch_width();
        assert!(width >= 1.0, "mean width {width}");
    }

    /// One quick chaos drill: kills + a stall + a checkpoint corruption
    /// + malformed requests, still bit-identical after recovery. The
    /// kill-at-every-seq sweep lives in
    /// `rust/tests/integration_recovery.rs`.
    #[test]
    fn default_chaos_soak_recovers_and_agrees() {
        let cfg = ChaosSoakConfig {
            soak: SoakConfig { events: 400, warmup_epochs: 2, ..Default::default() },
            checkpoint_every: 16,
            malformed_every: 41,
            ..Default::default()
        };
        let rep = run_chaos_soak(&cfg).unwrap();
        assert!(!rep.plan.events.is_empty());
        assert!(rep.drive.quarantined > 0, "malformed injection must fire");
        assert!(
            rep.agrees(),
            "{} mismatches, replicas_match={}, accounting={}",
            rep.mismatches,
            rep.replicas_match_oracle,
            rep.accounting_exact
        );
        assert!(
            rep.recovery.recoveries >= rep.recovery.worker_panics.min(1),
            "fired kills must be recovered"
        );
    }

    /// One quick network chaos soak: the full connection-fault matrix
    /// (torn frames, half-open, disconnect, slow-loris, flood) over the
    /// sharded server must agree with the oracle arm on every outcome.
    /// The heavier per-fault × shard-fault matrix lives in
    /// `rust/tests/integration_net.rs`.
    #[test]
    fn default_net_soak_agrees_with_oracle() {
        let cfg = NetSoakConfig::default();
        let rep = run_net_soak(&cfg).unwrap();
        assert_eq!(rep.plan.faulted(), 5, "full matrix deals five faulted clients");
        assert!(rep.server.infers > 0 && rep.server.learns > 0, "{:?}", rep.server);
        assert!(
            rep.agrees(),
            "mismatches={} stats_match={} replicas={} accounting={}\nserver {:?}\noracle {:?}",
            rep.outcome_mismatches,
            rep.stats_match,
            rep.replicas_match,
            rep.accounting_exact,
            rep.server,
            rep.oracle
        );
    }

    /// The tentpole acceptance: four tenants interleaved on one hub
    /// under a two-replica budget, forced evictions mid-trace, and every
    /// tenant still bit-identical to its private oracle — responses,
    /// per-segment drive stats and final replica digest.
    #[test]
    fn default_hub_soak_agrees_per_tenant() {
        let cfg = HubSoakConfig::default();
        let rep = run_hub_soak(&cfg).unwrap();
        assert_eq!(rep.tenants.len(), 4);
        for t in &rep.tenants {
            assert!(
                t.mismatches == 0 && t.stats_match && t.digest_match,
                "tenant diverged from its oracle: {t:?}"
            );
            assert!(t.responses > 0, "{t:?}");
            assert!(
                t.evictions >= 1 && t.rehydrations >= 1,
                "eviction/rehydration must fire mid-trace for every tenant: {t:?}"
            );
        }
        assert!(rep.agrees());
    }

    /// A reduced crash sweep (full traces, every durable write
    /// boundary) proving bit-identical restart; the ≥100-point
    /// acceptance sweep lives in `rust/tests/integration_store.rs`.
    #[test]
    fn default_restart_soak_is_bit_identical_across_crashes() {
        let cfg = RestartSoakConfig {
            events_per_tenant: 40,
            data_dir: std::env::temp_dir()
                .join(format!("tmfpga_restart_soak_unit_{}", std::process::id())),
            ..Default::default()
        };
        let rep = run_restart_soak(&cfg).unwrap();
        assert!(rep.agrees(), "{rep:?}");
        assert_eq!(rep.crashes_observed, rep.crash_points, "{rep:?}");
        assert!(rep.durable_ops >= 30, "{rep:?}");
        assert!(rep.models_recovered > 0, "{rep:?}");
        assert!(rep.wal_records_replayed > 0, "{rep:?}");
        assert!(rep.torn_tails_truncated > 0, "crash-mid-append must leave torn tails: {rep:?}");
    }

    /// The kill-and-relaunch drill's unit, in-process: crash at a fixed
    /// durable write, then a second pass over the *same* directory
    /// recovers, resumes mid-trace and matches the oracle.
    #[test]
    fn restart_once_crashes_then_resumes_in_place() {
        let cfg = RestartSoakConfig {
            events_per_tenant: 30,
            data_dir: std::env::temp_dir()
                .join(format!("tmfpga_restart_once_unit_{}", std::process::id())),
            ..Default::default()
        };
        std::fs::remove_dir_all(&cfg.data_dir).ok();
        let run = run_restart_once(&cfg, Some(25)).unwrap();
        assert!(run.crashed, "{run:?}");
        assert_eq!(run.divergences, 0, "{run:?}");
        let run = run_restart_once(&cfg, None).unwrap();
        assert!(!run.crashed, "{run:?}");
        assert_eq!(run.divergences, 0, "{run:?}");
        assert!(run.answered > 0, "{run:?}");
        let recovery = run.recovery.expect("clean pass reports recovery");
        assert!(recovery.models_recovered >= 1, "{recovery:?}");
        std::fs::remove_dir_all(&cfg.data_dir).ok();
    }
}
