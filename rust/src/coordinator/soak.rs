//! Deterministic replay/soak driver for the serving layer.
//!
//! Builds a warm-trained machine, generates a seeded Poisson-ish arrival
//! trace off the modular online input interface (ROM source → geometric
//! gaps, no wall clock), drives it through the sharded micro-batching
//! server, and cross-checks **every** response bit-identically against
//! the scalar [`ScalarOracle`] fed the same sequence. Because every
//! moving part is deterministic — trace generation, batching decisions,
//! the sequenced replica update log — a soak either agrees exactly or
//! has found a real ordering/replication bug; there is no tolerance
//! band.

use crate::data::blocks::{BlockPlan, SetAllocation};
use crate::data::filter::ClassFilter;
use crate::data::iris;
use crate::data::online::{arrival_trace, RomSource, TraceConfig};
use crate::serve::{
    run_trace, BatcherConfig, DriveStats, ScalarOracle, ServeConfig, ServeEvent, ShardServer,
    ShardStats,
};
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::Xoshiro256;
use crate::tm::update::UpdateKind;
use anyhow::Result;
use std::time::Instant;

/// Soak-run configuration (iris shape, paper-offline params).
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Shard replicas in the server under test.
    pub shards: usize,
    /// Arrival-trace length (requests + labelled updates).
    pub events: usize,
    /// Micro-batch lane cap, 1..=64.
    pub max_batch: usize,
    /// Flush deadline in virtual ticks.
    pub latency_budget: u64,
    /// Fraction of arrivals that carry a label (online updates).
    pub labelled_fraction: f32,
    /// Mean inter-arrival gap in ticks (0 = a single burst).
    pub mean_gap: f64,
    /// Master seed: warm-up training, trace generation and the replica
    /// update log all derive from it.
    pub seed: u64,
    /// Offline epochs to warm-train the served machine first, so
    /// predictions are non-trivial.
    pub warmup_epochs: usize,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            shards: 2,
            events: 1000,
            max_batch: 64,
            latency_budget: 8,
            labelled_fraction: 0.2,
            mean_gap: 1.0,
            seed: 42,
            warmup_epochs: 4,
        }
    }
}

/// What one soak run produced and whether it agreed with the oracle.
#[derive(Debug, Clone)]
pub struct SoakReport {
    /// Driver counters (flush breakdown, achieved batch width).
    pub drive: DriveStats,
    /// Server responses, sorted by request id.
    pub responses: Vec<(u64, usize)>,
    /// Per-shard work counters.
    pub shards: Vec<ShardStats>,
    /// Id-matched differences vs the scalar oracle: wrong predictions
    /// plus rows present on only one side, each counted once.
    pub mismatches: usize,
    /// Wall-clock seconds of the server arm (drive + join), for the
    /// throughput line; never used in any decision.
    pub wall_s: f64,
}

impl SoakReport {
    /// Bit-identical agreement with the scalar oracle.
    pub fn agrees(&self) -> bool {
        self.mismatches == 0
    }

    /// Served inference samples per wall-clock second.
    pub fn samples_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.responses.len() as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Build the soak's event stream: warm-trained machine + packed trace.
fn soak_events(cfg: &SoakConfig, shape: &TmShape) -> Result<(MultiTm, Vec<ServeEvent>)> {
    let params = TmParams::paper_offline(shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, cfg.seed)?;
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper())?;
    let train = sets.offline.pack(shape);
    let mut tm = MultiTm::new(shape)?;
    let mut rng = Xoshiro256::new(cfg.seed);
    for _ in 0..cfg.warmup_epochs {
        tm.train_epoch(&train, &params, &mut rng);
    }
    let mut source = RomSource::new(iris::booleanised().clone(), ClassFilter::disabled())?;
    let trace = arrival_trace(
        &mut source,
        &TraceConfig {
            events: cfg.events,
            labelled_fraction: cfg.labelled_fraction,
            mean_gap: cfg.mean_gap,
            seed: cfg.seed ^ 0x7ACE_7ACE,
        },
    )?;
    let events = trace
        .events
        .iter()
        .map(|e| {
            let input = Input::pack(shape, &e.bits);
            match e.label {
                Some(label) => ServeEvent::Update {
                    at_tick: e.at_tick,
                    kind: UpdateKind::Learn { input, label },
                },
                None => ServeEvent::Infer { at_tick: e.at_tick, input },
            }
        })
        .collect();
    Ok((tm, events))
}

/// Run one soak: sharded server vs scalar oracle on the same trace.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let bcfg = BatcherConfig { max_batch: cfg.max_batch, latency_budget: cfg.latency_budget };
    bcfg.validate()?;
    let (tm, events) = soak_events(cfg, &shape)?;

    let scfg = ServeConfig { shards: cfg.shards, params: params.clone(), base_seed: cfg.seed };
    let mut server = ShardServer::new(&tm, &scfg)?;
    let t0 = Instant::now();
    let drive = run_trace(&mut server, &events, &bcfg);
    let outcome = server.finish()?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut oracle = ScalarOracle::new(tm, params, cfg.seed);
    run_trace(&mut oracle, &events, &bcfg);
    let expected = oracle.into_responses();

    // Id-matched diff over the two id-sorted response lists: a wrong
    // prediction counts once, and a dropped/extra row counts once —
    // without skewing every later comparison the way a positional zip
    // would after a single lost response.
    let (a, b) = (&outcome.responses, &expected);
    let (mut i, mut j, mut mismatches) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].0.cmp(&b[j].0) {
            std::cmp::Ordering::Equal => {
                if a[i].1 != b[j].1 {
                    mismatches += 1;
                }
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => {
                mismatches += 1;
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                mismatches += 1;
                j += 1;
            }
        }
    }
    mismatches += (a.len() - i) + (b.len() - j);

    Ok(SoakReport {
        drive,
        responses: outcome.responses,
        shards: outcome.shards,
        mismatches,
        wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One quick end-to-end agreement check; the heavy differential
    /// matrix (shard counts × batch widths × fault injection) lives in
    /// `rust/tests/integration_serve.rs`.
    #[test]
    fn default_soak_agrees_with_oracle() {
        let cfg = SoakConfig { events: 300, warmup_epochs: 2, ..Default::default() };
        let rep = run_soak(&cfg).unwrap();
        assert!(rep.agrees(), "{} mismatches", rep.mismatches);
        assert!(rep.drive.infer_requests > 0 && rep.drive.updates > 0);
        assert_eq!(rep.responses.len() as u64, rep.drive.infer_requests);
        assert_eq!(rep.drive.width_sum, rep.drive.infer_requests);
        let width = rep.drive.mean_batch_width();
        assert!(width >= 1.0, "mean width {width}");
    }
}
