//! The L3 coordinator: experiment orchestration over the FPGA system model
//! (Fig-3 flow staging, 120-ordering cross-validation fan-out,
//! hyper-parameter search, §6 perf/power tables, and the paper's
//! future-work extensions: replay and continuous accuracy monitoring).

pub mod experiment;
pub mod metrics;
pub mod monitor;
pub mod perf;
pub mod replay;
pub mod report;
pub mod soak;
pub mod sweep;
pub mod unlabelled;

pub use experiment::{configure, run_figure, Figure, FigureResult, SweepOptions};
pub use metrics::{Curve, Stat};
pub use monitor::{monitor_and_retrain, AccuracyMonitor, RetrainPolicy};
pub use perf::{
    baseline_row, durable_cold_start_comparison, engine_row, fpga_model_row, native_row,
    perf_table, pjrt_epoch_row, pjrt_row, plane_comparison, plane_infer_row, power_table,
    recovery_comparison, serve_comparison,
};
pub use replay::{retention, run_with_replay};
pub use soak::{
    run_chaos_soak, run_hub_soak, run_net_soak, run_restart_once, run_restart_soak, run_soak,
    ChaosReport, ChaosSoakConfig, HubSoakConfig, HubSoakReport, NetSoakConfig, NetSoakReport,
    RestartRun, RestartSoakConfig, RestartSoakReport, SoakConfig, SoakReport, TenantReport,
};
pub use report::{figure_csv, figure_summary, sparkline, write_figure_csv};
pub use sweep::{run_sweep, sweep_csv, SweepConfig, SweepPoint};
pub use unlabelled::{confidence, unlabelled_pass, Confidence, PseudoLabelPolicy, UnseenClassDetector};
