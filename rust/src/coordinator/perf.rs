//! Performance & power table drivers (paper §6).
//!
//! Regenerates the section's claims as measurable rows:
//! - the hardware model's cycle counts (2-cycle inference+feedback,
//!   1 datapoint/clock pipelined) and the datapoints/s they imply at the
//!   reference clock;
//! - measured software throughput: optimized native path, the
//!   sample-sliced bitplane inference engine, naive scalar baseline, and
//!   the PJRT (AOT artifact) path;
//! - the power decomposition (1.725 W total / 1.4 W MCU in the paper) and
//!   the clock-gating / over-provisioning savings.

use crate::baseline::naive::NaiveTm;
use crate::data::blocks::{BlockPlan, SetAllocation};
use crate::data::iris;
use crate::fpga::clock::{Clock, Module};
use crate::fpga::fsm_low::DatapointEngine;
use crate::fpga::power::{PowerModel, REFERENCE_CLK_HZ};
use crate::fpga::system::{FpgaSystem, SystemConfig};
use crate::tm::bitplane::{BitPlanes, PlaneBatch};
use crate::tm::clause::{EvalMode, Input};
use crate::tm::feedback::train_step;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::{StepRands, Xoshiro256};
use anyhow::{bail, Result};
use std::time::Instant;

/// One row of the §6 performance table.
#[derive(Debug, Clone)]
pub struct PerfRow {
    pub path: String,
    /// Training datapoints per second.
    pub train_dps: f64,
    /// Inference datapoints per second.
    pub infer_dps: f64,
    pub note: String,
}

fn bench_data(shape: &TmShape) -> Result<Vec<(crate::tm::clause::Input, usize)>> {
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 21)?;
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper())?;
    Ok(sets.online.pack(shape))
}

/// Measured throughput of the optimized native path.
pub fn native_row(iters: usize) -> Result<PerfRow> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(1);
    let mut rands = StepRands::draw(&mut rng, &shape);

    let t0 = Instant::now();
    let mut n = 0u64;
    for _ in 0..iters {
        for (x, y) in &data {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &rands);
            n += 1;
        }
    }
    let train_dps = n as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut n = 0u64;
    let mut sink = 0usize;
    for _ in 0..iters * 4 {
        for (x, _) in &data {
            sink = sink.wrapping_add(tm.predict(x, &params));
            n += 1;
        }
    }
    let infer_dps = n as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    Ok(PerfRow {
        path: "rust native (scalar oracle)".into(),
        train_dps,
        infer_dps,
        note: "eager StepRands + per-literal feedback (L2 parity twin)".into(),
    })
}

/// Measured throughput of the word-parallel engine: lazy step randomness
/// (bit-sliced Bernoulli masks, drawn only for selected clauses) +
/// word-batched TA feedback for training, and the class-fanned batched
/// inference path.
pub fn engine_row(iters: usize) -> Result<PerfRow> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(1);

    let t0 = Instant::now();
    let mut n = 0u64;
    for _ in 0..iters {
        let stats = tm.train_epoch(&data, &params, &mut rng);
        n += stats.steps as u64;
    }
    let train_dps = n as f64 / t0.elapsed().as_secs_f64();

    let inputs: Vec<Input> = data.iter().map(|(x, _)| x.clone()).collect();
    let t0 = Instant::now();
    let mut n = 0u64;
    let mut sink = 0usize;
    for _ in 0..iters * 4 {
        let preds = tm.predict_batch(&inputs, &params);
        sink = sink.wrapping_add(preds.iter().sum::<usize>());
        n += preds.len() as u64;
    }
    let infer_dps = n as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    Ok(PerfRow {
        path: "rust native (word-parallel engine)".into(),
        train_dps,
        infer_dps,
        note: "lazy bit-sliced rands + word-batched feedback".into(),
    })
}

/// Train a machine to realistic include density (an untrained machine
/// has only empty clauses, which every inference path short-circuits —
/// benchmarking it would flatter all kernels equally and mean nothing).
fn trained_machine(
    shape: &TmShape,
    params: &TmParams,
    data: &[(Input, usize)],
) -> Result<MultiTm> {
    let mut tm = MultiTm::new(shape)?;
    let mut rng = Xoshiro256::new(1);
    for _ in 0..10 {
        tm.train_epoch(data, params, &mut rng);
    }
    Ok(tm)
}

/// Measured throughput of the sample-sliced (bitplane) inference engine:
/// batched prediction off a once-transposed plane cache. Inference-only —
/// the train column is 0 (training stays on the word-parallel engine).
pub fn plane_infer_row(iters: usize) -> Result<PerfRow> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let tm = trained_machine(&shape, &params, &data)?;
    let batch = PlaneBatch::from_labelled(&shape, &data);

    let t0 = Instant::now();
    let mut n = 0u64;
    let mut sink = 0usize;
    for _ in 0..iters * 4 {
        let preds = tm.predict_planes(batch.planes(), &params);
        sink = sink.wrapping_add(preds.iter().sum::<usize>());
        n += preds.len() as u64;
    }
    let infer_dps = n as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    Ok(PerfRow {
        path: "rust native (sample-sliced planes)".into(),
        train_dps: 0.0,
        infer_dps,
        note: "64 samples per AND off cached dataset bitplanes".into(),
    })
}

/// The ISSUE-2 acceptance comparison: row-major `evaluate_batch` vs the
/// sample-sliced `evaluate_planes` on a `batch_rows`-row single-word
/// (iris-shaped) batch, on a realistically trained machine. Returns
/// `(row_major_rows_per_s, plane_rows_per_s, transpose_seconds)`; the
/// transpose is reported separately because the cached-plane drivers
/// amortise it across every rescore.
pub fn plane_comparison(batch_rows: usize, reps: usize) -> Result<(f64, f64, f64)> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let tm = trained_machine(&shape, &params, &data)?;
    let inputs: Vec<Input> =
        data.iter().map(|(x, _)| x.clone()).cycle().take(batch_rows).collect();

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(tm.evaluate_batch(&inputs, &params, EvalMode::Infer));
    }
    let row_major = (reps * inputs.len()) as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let planes = BitPlanes::from_inputs(&shape, &inputs);
    let transpose_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(tm.evaluate_planes(&planes, &params, EvalMode::Infer));
    }
    let plane = (reps * inputs.len()) as f64 / t0.elapsed().as_secs_f64();
    Ok((row_major, plane, transpose_s))
}

/// The ISSUE-3 acceptance comparison: the interleaved online-monitor
/// loop — one online training step followed by a full re-score of a
/// `batch_rows`-row cached plane batch — with the re-score done cold
/// (`evaluate_planes`, every clause re-ANDed every time) vs through the
/// incremental dirty-clause engine ([`crate::tm::rescore::RescoreCache`],
/// only flipped clauses re-ANDed). Both arms run the *same* training
/// schedule (same seed, same draws) on clones of a converged machine —
/// the regime the paper's T-threshold drives the online loop into, where
/// feedback (and therefore TA action flips) is rare. Only re-score time
/// is accumulated; the identical training steps are excluded from both
/// clocks. Returns `(cold_rescores_per_s, incremental_rescores_per_s,
/// measured_dirty_fraction)` and errors if the two arms' final sums ever
/// diverge (they are checked bit-identical).
pub fn online_monitor_comparison(batch_rows: usize, steps: usize) -> Result<(f64, f64, f64)> {
    use crate::tm::engine::train_step_fast;
    use crate::tm::rescore::RescoreCache;
    let shape = TmShape::iris();
    let p_train = TmParams::paper_online(&shape); // s = 1: the §5 online config
    let p_score = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let tm0 = trained_machine(&shape, &p_score, &data)?;
    let rows: Vec<(Input, usize)> =
        data.iter().cloned().cycle().take(batch_rows).collect();
    let batch = PlaneBatch::from_labelled(&shape, &rows);

    // Cold arm: full evaluate_planes after every step.
    let mut tm = tm0.clone();
    let mut rng = Xoshiro256::new(0x0113);
    let mut rands = StepRands::draw(&mut rng, &shape);
    let mut cold_t = std::time::Duration::ZERO;
    let mut cold_sums = Vec::new();
    for i in 0..steps {
        let (x, y) = &data[i % data.len()];
        rands.refill(&mut rng, &shape);
        train_step_fast(&mut tm, x, *y, &p_train, &rands);
        let t0 = Instant::now();
        cold_sums = tm.evaluate_planes(batch.planes(), &p_score, EvalMode::Infer);
        cold_t += t0.elapsed();
    }

    // Incremental arm: identical schedule, dirty-clause re-scoring.
    let mut tm = tm0.clone();
    let mut rng = Xoshiro256::new(0x0113);
    let mut rands = StepRands::draw(&mut rng, &shape);
    let mut cache = RescoreCache::new();
    let mut inc_t = std::time::Duration::ZERO;
    let mut inc_sums = Vec::new();
    for i in 0..steps {
        let (x, y) = &data[i % data.len()];
        rands.refill(&mut rng, &shape);
        train_step_fast(&mut tm, x, *y, &p_train, &rands);
        let t0 = Instant::now();
        inc_sums = cache.evaluate(&tm, batch.planes(), &p_score, EvalMode::Infer);
        inc_t += t0.elapsed();
    }
    if cold_sums != inc_sums {
        bail!("incremental re-score diverged from the cold full re-score");
    }
    Ok((
        steps as f64 / cold_t.as_secs_f64(),
        steps as f64 / inc_t.as_secs_f64(),
        cache.stats().dirty_fraction(),
    ))
}

/// The ISSUE-5 acceptance comparison: training epochs on a **converged**
/// machine — the per-step lazy engine (`train_step_lazy` loop, one full
/// clause evaluation per sample) vs the lane-speculative engine
/// (`MultiTm::train_plane_batch_lazy`: clause fired-masks batched 64
/// samples per AND, repaired only on mid-lane action flips). Both arms
/// consume the same generator draw for draw and are asserted
/// **bit-identical** at the end. The shape is multiword
/// (4 classes × 32 clauses × 128 literals) on a learnable prototype
/// workload: the regime where clause evaluation dominates the step and
/// the paper's T-threshold has made feedback — and therefore flips —
/// rare. The batch transpose is built once and reused across epochs,
/// as the wired drivers do. Returns `(per_step_steps_per_s,
/// lane_steps_per_s, mean_flips_per_lane)`.
pub fn train_lane_comparison(rows_n: usize, epochs: usize) -> Result<(f64, f64, f64)> {
    use crate::data::synthetic::prototype_dataset;
    use crate::tm::engine::{train_step_lazy, FeedbackPlan};
    use crate::tm::train_planes::TrainScratch;
    let shape = TmShape { classes: 4, max_clauses: 32, features: 64, states: 100 };
    let params = TmParams::paper_offline(&shape);
    let data = prototype_dataset(shape.classes, rows_n.div_ceil(shape.classes), 64, 0.03, 0xBEE5)?
        .pack(&shape);

    // Converge first (untimed): after these epochs the class sums sit at
    // the T clamp for most samples and p_sel ≈ 0 — the converged phase
    // the acceptance floor is defined over.
    let mut tm0 = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(11);
    for _ in 0..10 {
        tm0.train_epoch(&data, &params, &mut rng);
    }

    let plan = FeedbackPlan::new(&params);

    // Per-step arm.
    let mut tm_a = tm0.clone();
    let mut rng_a = Xoshiro256::new(0x17A);
    let t0 = Instant::now();
    for _ in 0..epochs {
        for (x, y) in &data {
            train_step_lazy(&mut tm_a, x, *y, &params, &plan, &mut rng_a);
        }
    }
    let per_step = (epochs * data.len()) as f64 / t0.elapsed().as_secs_f64();

    // Lane arm: same seed, same draws, cached transpose.
    let mut tm_b = tm0.clone();
    let mut rng_b = Xoshiro256::new(0x17A);
    let mut scratch = TrainScratch::new();
    let planes = BitPlanes::from_labelled(&shape, &data);
    let t0 = Instant::now();
    for _ in 0..epochs {
        tm_b.train_plane_batch_lazy(&data, &planes, &params, &plan, &mut rng_b, &mut scratch);
    }
    let lane = (epochs * data.len()) as f64 / t0.elapsed().as_secs_f64();
    if tm_a.ta().states() != tm_b.ta().states() {
        bail!("lane arm diverged from the per-step arm (must be bit-identical)");
    }
    Ok((per_step, lane, scratch.mean_flips_per_lane()))
}

/// The ISSUE-4 acceptance comparison: request-at-a-time serving through
/// the sharded micro-batching front door (`crate::serve`) on a
/// `requests`-request burst trace, on a realistically trained machine.
/// Three arms, all through the same server machinery so only the policy
/// differs: batch-1 on a single shard (the no-coalescing floor),
/// micro-batched (64-wide) on a single shard, and micro-batched across
/// `shards` shards. Each arm does one untimed warmup run and `reps`
/// timed runs, keeping the **fastest** — a full pool spawn + drive +
/// join per run, so single-shot thread-scheduling noise on shared CI
/// runners cannot feed the 25% bench-compare regression gate. Returns
/// `(batch1_rps, micro_1shard_rps, micro_sharded_rps, mean_width)` —
/// samples served per wall-clock second and the sharded arm's achieved
/// mean batch width.
pub fn serve_comparison(
    requests: usize,
    shards: usize,
    reps: usize,
) -> Result<(f64, f64, f64, f64)> {
    use crate::serve::{run_trace, BatcherConfig, ServeConfig, ServeEvent, ShardServer};
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let tm = trained_machine(&shape, &params, &data)?;
    let events: Vec<ServeEvent> = data
        .iter()
        .map(|(x, _)| x.clone())
        .cycle()
        .take(requests)
        .map(|input| ServeEvent::Infer { at_tick: 0, input })
        .collect();

    let arm = |n_shards: usize, max_batch: usize| -> Result<(f64, f64)> {
        let bcfg = BatcherConfig { max_batch, latency_budget: 1, ..Default::default() };
        let mut best = f64::INFINITY;
        let mut width = 0.0;
        for rep in 0..=reps.max(1) {
            let cfg = ServeConfig::new(n_shards, params.clone(), 7);
            let t0 = Instant::now();
            let mut server = ShardServer::new(&tm, &cfg)?;
            let drive = run_trace(&mut server, &events, &bcfg)?;
            let outcome = server.finish()?;
            let secs = t0.elapsed().as_secs_f64();
            if outcome.responses.len() != requests {
                bail!(
                    "serve arm answered {} of {requests} requests",
                    outcome.responses.len()
                );
            }
            if rep > 0 {
                best = best.min(secs); // rep 0 is the untimed warmup
            }
            width = drive.mean_batch_width();
        }
        Ok((requests as f64 / best, width))
    };
    let (batch1, w1) = arm(1, 1)?;
    debug_assert!((w1 - 1.0).abs() < 1e-9);
    let (micro_one, _) = arm(1, 64)?;
    let (micro_sharded, width) = arm(shards, 64)?;
    Ok((batch1, micro_one, micro_sharded, width))
}

/// The PR-6 recovery-latency scenario: checkpoint interval vs replay
/// cost. Builds a `total_updates`-long Learn log on a realistically
/// trained machine, checkpoints at the last multiple of `interval`
/// before the end of the log (the worst-case kill point for that
/// cadence; `interval = 0` means genesis-only, replaying everything),
/// then times exactly what `ShardServer` recovery does: decode + verify
/// the snapshot, replay the log suffix on `(base_seed, seq)`-keyed
/// randomness. Fastest of `reps` timed runs; returns
/// `(seconds, replayed_updates)`. Each run's recovered state is checked
/// identical across reps — timing a nondeterministic recovery would be
/// meaningless.
pub fn recovery_comparison(total_updates: u64, interval: u64, reps: usize) -> Result<(f64, u64)> {
    use crate::serve::{restore, snapshot_bytes};
    use crate::tm::update::{ShardUpdate, UpdateKind};
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let tm = trained_machine(&shape, &params, &data)?;
    let base_seed = 7u64;
    let log: Vec<ShardUpdate> = (1..=total_updates)
        .map(|seq| {
            let (x, y) = &data[(seq as usize - 1) % data.len()];
            ShardUpdate { seq, kind: UpdateKind::Learn { input: x.clone(), label: *y } }
        })
        .collect();
    let ckpt_seq = if interval == 0 {
        0
    } else {
        (total_updates.saturating_sub(1) / interval) * interval
    };
    let mut live = tm.clone();
    let mut rands: Option<StepRands> = None;
    for u in &log[..ckpt_seq as usize] {
        live.apply_update_with(u, &params, base_seed, &mut rands);
    }
    let snap = snapshot_bytes(&live, &params, ckpt_seq);
    let replayed = total_updates - ckpt_seq;

    let mut best = f64::INFINITY;
    let mut digest: Option<u64> = None;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let mut restored = restore(&snap)?;
        let mut r: Option<StepRands> = None;
        for u in &log[ckpt_seq as usize..] {
            restored.machine.apply_update_with(u, &params, base_seed, &mut r);
        }
        best = best.min(t0.elapsed().as_secs_f64());
        let d = restored.machine.state_digest();
        if let Some(prev) = digest {
            if prev != d {
                bail!("recovery must be deterministic across reps");
            }
        }
        digest = Some(d);
    }
    Ok((best, replayed))
}

/// The PR-10 restart-latency scenario: the durable hub's full cold
/// start as a function of its checkpoint cadence. Builds a real data
/// directory by streaming `total_updates` Learn updates through a
/// write-ahead [`ModelHub`](crate::hub::ModelHub) (so the WAL segments,
/// checkpoints and manifest on disk are exactly what a production run
/// leaves behind), then times what a relaunched process does end to
/// end: `Store::open` (segment scan, torn-tail check, manifest +
/// checkpoint CRC verification) plus `ModelHub::open_durable` and the
/// first digest touch (snapshot restore + keyed WAL-suffix replay).
/// `checkpoint_every = 0` disables cadence refresh — genesis-only,
/// replaying the whole log. Fastest of `reps` timed runs; returns
/// `(seconds, replayed_updates)`; the rebuilt digest is checked
/// identical across reps.
pub fn durable_cold_start_comparison(
    total_updates: u64,
    checkpoint_every: u64,
    reps: usize,
) -> Result<(f64, u64)> {
    use crate::hub::{HubConfig, ModelHub};
    use crate::store::{RealDisk, Store, StoreConfig};
    use crate::tm::update::UpdateKind;
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let tm = trained_machine(&shape, &params, &data)?;
    let base_seed = 7u64;
    let dir = std::env::temp_dir()
        .join(format!("tmfpga-perf-cold-start-{}-{checkpoint_every}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store_cfg = StoreConfig::default();
    let hub_cfg = HubConfig { memory_budget: 0, checkpoint_every, plane_cache_batches: 4 };
    fn ctx<E: std::fmt::Display>(what: &'static str) -> impl Fn(E) -> anyhow::Error {
        move |e| anyhow::anyhow!("cold-start bench: {what}: {e}")
    }

    let (store, recovered) =
        Store::open(Box::new(RealDisk), &dir, store_cfg).map_err(ctx("open fresh store"))?;
    let mut hub = ModelHub::open_durable(hub_cfg.clone(), store, recovered)
        .map_err(ctx("open fresh hub"))?;
    let h = hub.create("bench", tm, params, base_seed).map_err(ctx("create"))?;
    for seq in 1..=total_updates {
        let (x, y) = &data[(seq as usize - 1) % data.len()];
        hub.update(h, UpdateKind::Learn { input: x.clone(), label: *y }).map_err(ctx("update"))?;
    }
    hub.sync_durable().map_err(ctx("sync"))?;
    drop(hub);

    let mut best = f64::INFINITY;
    let mut replayed = 0u64;
    let mut digest: Option<u64> = None;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let (store, recovered) =
            Store::open(Box::new(RealDisk), &dir, store_cfg).map_err(ctx("cold open"))?;
        replayed = recovered.iter().map(|m| m.ops.len() as u64).sum();
        let mut hub = ModelHub::open_durable(hub_cfg.clone(), store, recovered)
            .map_err(ctx("cold hub"))?;
        let hb = hub
            .resolve("bench")
            .ok_or_else(|| anyhow::anyhow!("cold-start bench: model lost across restart"))?;
        let d = hub.digest(hb).map_err(ctx("digest"))?;
        best = best.min(t0.elapsed().as_secs_f64());
        if hub.model_seq(hb) != Some(total_updates) {
            bail!("cold start came back at the wrong seq");
        }
        if let Some(prev) = digest {
            if prev != d {
                bail!("cold start must be deterministic across reps");
            }
        }
        digest = Some(d);
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok((best, replayed))
}

/// Measured throughput of the naive scalar baseline.
pub fn baseline_row(iters: usize) -> Result<PerfRow> {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let mut tm = NaiveTm::new(&shape);
    let mut rng = Xoshiro256::new(1);
    let mut rands = StepRands::draw(&mut rng, &shape);

    let t0 = Instant::now();
    let mut n = 0u64;
    for _ in 0..iters {
        for (x, y) in &data {
            rands.refill(&mut rng, &shape);
            tm.train_step(x, *y, &params, &rands);
            n += 1;
        }
    }
    let train_dps = n as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut n = 0u64;
    let mut sink = 0usize;
    for _ in 0..iters {
        for (x, _) in &data {
            sink = sink.wrapping_add(tm.predict(x, &params));
            n += 1;
        }
    }
    let infer_dps = n as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    Ok(PerfRow {
        path: "software baseline (naive scalar)".into(),
        train_dps,
        infer_dps,
        note: "the paper's software comparator".into(),
    })
}

/// The modelled FPGA: 1 datapoint/clock pipelined at the reference clock.
pub fn fpga_model_row() -> PerfRow {
    let dps = REFERENCE_CLK_HZ / (DatapointEngine::pipelined_cycles(1_000_000) as f64
        / 1_000_000.0);
    PerfRow {
        path: "FPGA model @100 MHz".into(),
        train_dps: dps,
        infer_dps: dps,
        note: "2-cycle datapath, 1 datapoint/clock pipelined (§6)".into(),
    }
}

/// Measured PJRT (AOT artifact) throughput, when artifacts exist.
pub fn pjrt_row(steps: usize) -> Result<Option<PerfRow>> {
    let dir = crate::runtime::default_artifacts_dir();
    if !dir.join("meta.json").exists() {
        return Ok(None);
    }
    let client = crate::runtime::Client::cpu()?;
    let exe = crate::runtime::TmExecutor::load(&client, &dir)?;
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_offline(&shape);
    let data = bench_data(&shape)?;
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(1);

    let t0 = Instant::now();
    let mut n = 0u64;
    'outer: loop {
        for (x, y) in &data {
            let r = StepRands::draw(&mut rng, &shape);
            let next = exe.train_step(&tm, x, *y, &params, &r)?;
            tm = MultiTm::from_states(&shape, next)?;
            n += 1;
            if n as usize >= steps {
                break 'outer;
            }
        }
    }
    let train_dps = n as f64 / t0.elapsed().as_secs_f64();

    // Batched inference via the eval artifact (amortized dispatch).
    let t0 = Instant::now();
    let mut rows = 0u64;
    for _ in 0..steps.max(10) {
        let (_, _) = exe.eval_batch(&tm, &data, &params)?;
        rows += data.len() as u64;
    }
    let infer_dps = rows as f64 / t0.elapsed().as_secs_f64();
    Ok(Some(PerfRow {
        path: "PJRT AOT artifacts (CPU)".into(),
        train_dps,
        infer_dps,
        note: "per-step dispatch dominates; infer batched".into(),
    }))
}

/// Measured PJRT throughput with the scan (epoch) artifact: one dispatch
/// per pass instead of one per datapoint.
pub fn pjrt_epoch_row(passes: usize) -> Result<Option<PerfRow>> {
    let dir = crate::runtime::default_artifacts_dir();
    if !dir.join("meta.json").exists() {
        return Ok(None);
    }
    let client = crate::runtime::Client::cpu()?;
    let exe = crate::runtime::TmExecutor::load(&client, &dir)?;
    if exe.meta.epoch_steps == 0 {
        return Ok(None);
    }
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_online(&shape);
    let data = bench_data(&shape)?;
    let n = exe.meta.epoch_steps.min(data.len());
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(2);

    let t0 = Instant::now();
    let mut trained = 0u64;
    for _ in 0..passes {
        let steps: Vec<_> = data
            .iter()
            .take(n)
            .map(|(x, y)| (x.clone(), *y, StepRands::draw(&mut rng, &shape)))
            .collect();
        let next = exe.train_epoch(&tm, &steps, &params)?;
        tm = MultiTm::from_states(&shape, next)?;
        trained += n as u64;
    }
    let train_dps = trained as f64 / t0.elapsed().as_secs_f64();
    Ok(Some(PerfRow {
        path: "PJRT scan artifact (epoch/dispatch)".into(),
        train_dps,
        infer_dps: 0.0,
        note: format!("{n} steps per dispatch (lax.scan)"),
    }))
}

/// Render the §6 performance table.
pub fn perf_table(rows: &[PerfRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<34} {:>14} {:>14}  note\n",
        "path", "train dp/s", "infer dp/s"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<34} {:>14.0} {:>14.0}  {}\n",
            r.path, r.train_dps, r.infer_dps, r.note
        ));
    }
    s
}

/// One row of the §6 power table.
#[derive(Debug, Clone)]
pub struct PowerRow {
    pub scenario: String,
    pub total_w: f64,
    pub mcu_w: f64,
    pub fabric_w: f64,
}

/// Regenerate the power decomposition: paper run, idle (fully gated),
/// no-gating worst case, and the over-provisioning slice.
pub fn power_table() -> Result<Vec<PowerRow>> {
    let model = PowerModel::default();
    let mut rows = Vec::new();

    // The paper's experimental run.
    let mut cfg = SystemConfig::paper();
    cfg.online_iterations = 4;
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42)?;
    let blocks: Vec<_> = (0..5).map(|i| plan.block(i).clone()).collect();
    let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4])?;
    let rep = sys.run()?;
    rows.push(PowerRow {
        scenario: "paper run (clock gated)".into(),
        total_w: rep.power.total_w,
        mcu_w: rep.power.mcu_w,
        fabric_w: rep.power.fabric_w,
    });

    // Idle: everything gated.
    let mut idle = Clock::new();
    idle.advance(1_000_000);
    let p = model.estimate(&idle);
    rows.push(PowerRow {
        scenario: "idle (TM fully gated)".into(),
        total_w: p.total_w,
        mcu_w: p.mcu_w,
        fabric_w: p.fabric_w,
    });

    // No gating: all modules clocked the whole time.
    let mut hot = Clock::new();
    for m in crate::fpga::clock::ALL_MODULES {
        hot.set_enabled(m, true);
    }
    hot.advance(1_000_000);
    let p = model.estimate(&hot);
    rows.push(PowerRow {
        scenario: "no clock gating (worst case)".into(),
        total_w: p.total_w,
        mcu_w: p.mcu_w,
        fabric_w: p.fabric_w,
    });

    // Over-provisioned slice un-gated vs gated.
    let mut op = Clock::new();
    op.set_enabled(Module::TmCore, true);
    op.set_enabled(Module::TmOverProvision, true);
    op.advance(1_000_000);
    let p = model.estimate(&op);
    rows.push(PowerRow {
        scenario: "over-provisioned clauses un-gated".into(),
        total_w: p.total_w,
        mcu_w: p.mcu_w,
        fabric_w: p.fabric_w,
    });
    Ok(rows)
}

pub fn power_table_text(rows: &[PowerRow]) -> String {
    let mut s = format!(
        "{:<36} {:>9} {:>8} {:>9}\n",
        "scenario", "total W", "MCU W", "fabric W"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<36} {:>9.3} {:>8.3} {:>9.3}\n",
            r.scenario, r.total_w, r.mcu_w, r.fabric_w
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_model_is_one_per_clock() {
        let r = fpga_model_row();
        assert!((r.train_dps - REFERENCE_CLK_HZ).abs() / REFERENCE_CLK_HZ < 0.01);
    }

    #[test]
    fn native_beats_naive() {
        let native = native_row(3).unwrap();
        let naive = baseline_row(3).unwrap();
        assert!(
            native.infer_dps > naive.infer_dps,
            "bit-parallel {:.0} should beat naive {:.0}",
            native.infer_dps,
            naive.infer_dps
        );
        assert!(native.train_dps > 0.0 && naive.train_dps > 0.0);
    }

    #[test]
    fn plane_rows_measure_real_throughput() {
        // As with engine_row: wall-clock ratio assertions live in the
        // perf_table bench at realistic iteration counts; here only
        // sanity-check the measurement plumbing.
        let r = plane_infer_row(3).unwrap();
        assert!(r.infer_dps > 0.0);
        assert_eq!(r.train_dps, 0.0, "plane path is inference-only");
        assert!(r.path.contains("sample-sliced"));
        let (row_major, plane, transpose_s) = plane_comparison(256, 2).unwrap();
        assert!(row_major > 0.0 && plane > 0.0);
        assert!(transpose_s >= 0.0);
    }

    #[test]
    fn online_monitor_comparison_measures_and_agrees() {
        // Bit-identity of the two arms is asserted inside the driver; the
        // ≥5× wall-clock acceptance lives in the perf_table bench at
        // realistic batch/step counts (timing assertions in `cargo test`
        // are flaky by construction).
        let (cold, inc, dirty) = online_monitor_comparison(256, 6).unwrap();
        assert!(cold > 0.0 && inc > 0.0);
        assert!((0.0..=1.0).contains(&dirty), "dirty fraction {dirty}");
    }

    #[test]
    fn train_lane_comparison_measures_and_agrees() {
        // Bit-identity of the two arms is asserted inside the driver;
        // the ≥3× wall-clock acceptance lives in the perf_table bench at
        // realistic row/epoch counts (timing assertions in `cargo test`
        // are flaky by construction).
        let (per_step, lane, flips) = train_lane_comparison(128, 1).unwrap();
        assert!(per_step > 0.0 && lane > 0.0);
        assert!(flips >= 0.0, "mean flips/lane {flips}");
    }

    #[test]
    fn serve_comparison_measures_and_answers_everything() {
        // Wall-clock ratio acceptance (≥3× micro-batch floor) lives in
        // the perf_table bench at realistic request counts; here only
        // sanity-check the plumbing (every arm answers every request —
        // asserted inside — and rates/width are sane).
        let (batch1, micro_one, micro_sharded, width) = serve_comparison(192, 2, 1).unwrap();
        assert!(batch1 > 0.0 && micro_one > 0.0 && micro_sharded > 0.0);
        assert!(
            (1.0..=64.0).contains(&width),
            "mean micro-batch width {width} out of range"
        );
    }

    #[test]
    fn engine_row_measures_real_throughput() {
        // The ≥5× acceptance (and any ordering assertion) lives in the
        // perf_table bench at realistic iteration counts — wall-clock
        // comparisons inside `cargo test` on shared CI runners are
        // flaky by construction, so here only sanity-check the row.
        let engine = engine_row(6).unwrap();
        assert!(engine.train_dps > 0.0);
        assert!(engine.infer_dps > 0.0);
        assert!(engine.path.contains("word-parallel"));
    }

    #[test]
    fn power_table_shape_matches_paper() {
        let rows = power_table().unwrap();
        assert_eq!(rows.len(), 4);
        let paper = &rows[0];
        assert!(
            (1.45..=1.95).contains(&paper.total_w),
            "paper scenario {:.3} W near 1.725 W",
            paper.total_w
        );
        assert_eq!(paper.mcu_w, 1.4);
        let idle = &rows[1];
        let hot = &rows[2];
        assert!(idle.fabric_w < paper.fabric_w, "gating saves power vs active");
        assert!(hot.fabric_w > paper.fabric_w, "no gating costs more");
        let table = power_table_text(&rows);
        assert!(table.contains("paper run"));
    }
}
