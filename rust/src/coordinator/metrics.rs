//! Curve statistics for cross-validated experiments: every figure in the
//! paper is a mean over 120 block orderings; we also carry the standard
//! deviation for error bars the paper omits.

/// Mean/std/min/max of one analysis point across orderings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stat {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Stat {
    pub fn from_samples(xs: &[f64]) -> Stat {
        let n = xs.len();
        if n == 0 {
            return Stat { mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN, n: 0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stat {
            mean,
            std: var.sqrt(),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            n,
        }
    }
}

/// One averaged accuracy curve (index = online iteration, 0 = after
/// offline training).
#[derive(Debug, Clone)]
pub struct Curve {
    pub points: Vec<Stat>,
}

impl Curve {
    /// Aggregate per-ordering curves (all the same length).
    pub fn aggregate(runs: &[Vec<f64>]) -> Curve {
        assert!(!runs.is_empty());
        let len = runs[0].len();
        assert!(runs.iter().all(|r| r.len() == len), "ragged curves");
        let points = (0..len)
            .map(|i| {
                let samples: Vec<f64> =
                    runs.iter().map(|r| r[i]).filter(|x| x.is_finite()).collect();
                Stat::from_samples(&samples)
            })
            .collect();
        Curve { points }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn mean_at(&self, i: usize) -> f64 {
        self.points[i].mean
    }

    /// Net accuracy change over the curve (the paper's "+12%" deltas).
    pub fn delta(&self) -> f64 {
        match (self.points.first(), self.points.last()) {
            (Some(first), Some(last)) if self.points.len() >= 2 => last.mean - first.mean,
            _ => 0.0,
        }
    }

    /// Largest single-step drop (used to locate fault/class events).
    pub fn max_drop(&self) -> (usize, f64) {
        let mut worst = (0usize, 0.0f64);
        for i in 1..self.points.len() {
            let d = self.points[i].mean - self.points[i - 1].mean;
            if d < worst.1 {
                worst = (i, d);
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_basics() {
        let s = Stat::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
        assert!(Stat::from_samples(&[]).mean.is_nan());
    }

    #[test]
    fn aggregate_and_delta() {
        let runs = vec![vec![0.5, 0.6, 0.7], vec![0.7, 0.8, 0.9]];
        let c = Curve::aggregate(&runs);
        assert_eq!(c.len(), 3);
        assert!((c.mean_at(0) - 0.6).abs() < 1e-12);
        assert!((c.delta() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn nan_points_skipped() {
        let runs = vec![vec![0.5, f64::NAN], vec![0.7, 0.9]];
        let c = Curve::aggregate(&runs);
        assert_eq!(c.points[1].n, 1);
        assert!((c.points[1].mean - 0.9).abs() < 1e-12);
    }

    #[test]
    fn max_drop_finds_event() {
        let runs = vec![vec![0.8, 0.82, 0.6, 0.7, 0.75]];
        let c = Curve::aggregate(&runs);
        let (at, d) = c.max_drop();
        assert_eq!(at, 2);
        assert!((d + 0.22).abs() < 1e-9);
    }
}
