//! Experiment drivers: one per figure of the paper's evaluation (§5),
//! each a specific staging of the FPGA system over the 120-ordering
//! cross-validation sweep (§3.6.1), fanned out across threads.
//!
//! Training inside each run goes through the word-parallel engine
//! (`tm::engine::train_step_fast` via `fpga::system`) — bit-identical to
//! the scalar oracle given the same `StepRands`, so every figure below is
//! unchanged from the oracle's output while running the fast datapath.
//! Accuracy analysis runs the incremental dirty-clause re-scorer over the
//! analyzer's per-(set, filter) transposed-plane cache (`fpga::accuracy`)
//! — each of the 17 analysis points per run rescores the same stored
//! sets, so the transpose is paid once per filter configuration, each
//! class sum costs one AND per 64 samples, and re-analyses only re-AND
//! the clauses whose TA actions flipped since the previous point
//! ([`FigureResult::mean_dirty_fraction`] reports how sparse that is
//! across the sweep).
//!
//! | Figure | Staging                                                        |
//! |--------|----------------------------------------------------------------|
//! | Fig 4  | labelled online learning, 16 iterations                        |
//! | Fig 5  | class 0 filtered throughout (baseline for §5.2)                |
//! | Fig 6  | class 0 introduced after 5 iterations, online learning **off** |
//! | Fig 7  | class 0 introduced after 5 iterations, online learning **on**  |
//! | Fig 8  | 20% stuck-at-0 TA faults after 5 iterations, learning **off**  |
//! | Fig 9  | 20% stuck-at-0 TA faults after 5 iterations, learning **on**   |

use crate::coordinator::metrics::Curve;
use crate::data::blocks::{all_orderings, BlockPlan};
use crate::data::dataset::BoolDataset;
use crate::data::iris;
use crate::fpga::mcu::McuAction;
use crate::fpga::system::{FpgaSystem, SystemConfig};
use crate::tm::fault::{Fault, FaultMap};
use anyhow::{bail, Context, Result};
use std::sync::mpsc;

/// The figures of §5 (plus `All`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Figure {
    Fig4,
    Fig5,
    Fig6,
    Fig7,
    Fig8,
    Fig9,
}

impl Figure {
    pub fn parse(s: &str) -> Result<Figure> {
        Ok(match s {
            "4" | "fig4" => Figure::Fig4,
            "5" | "fig5" => Figure::Fig5,
            "6" | "fig6" => Figure::Fig6,
            "7" | "fig7" => Figure::Fig7,
            "8" | "fig8" => Figure::Fig8,
            "9" | "fig9" => Figure::Fig9,
            _ => bail!("unknown figure {s:?} (expected 4..9)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Figure::Fig4 => "fig4",
            Figure::Fig5 => "fig5",
            Figure::Fig6 => "fig6",
            Figure::Fig7 => "fig7",
            Figure::Fig8 => "fig8",
            Figure::Fig9 => "fig9",
        }
    }

    pub fn title(&self) -> &'static str {
        match self {
            Figure::Fig4 => "Online learning with labelled data",
            Figure::Fig5 => "Class 0 filtered throughout (baseline)",
            Figure::Fig6 => "Class introduced at iter 5, online learning disabled",
            Figure::Fig7 => "Class introduced at iter 5, online learning enabled",
            Figure::Fig8 => "20% stuck-at-0 faults at iter 5, online learning disabled",
            Figure::Fig9 => "20% stuck-at-0 faults at iter 5, online learning enabled",
        }
    }

    pub fn all() -> [Figure; 6] {
        [Figure::Fig4, Figure::Fig5, Figure::Fig6, Figure::Fig7, Figure::Fig8, Figure::Fig9]
    }
}

/// Sweep options.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Orderings to run (≤ 120); the paper runs all 120.
    pub orderings: usize,
    /// Worker threads (0 = all cores).
    pub threads: usize,
    pub seed: u64,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions { orderings: 120, threads: 0, seed: 42 }
    }
}

/// Aggregated result of one figure.
#[derive(Debug, Clone)]
pub struct FigureResult {
    pub figure: Figure,
    pub offline: Curve,
    pub validation: Curve,
    pub online: Curve,
    /// Mean cycles per run and handshake stalls (perf cross-checks).
    pub mean_cycles: f64,
    pub mean_stall_cycles: f64,
    pub mean_power_w: f64,
    /// Mean fraction of clause visits the incremental re-scorer had to
    /// re-AND across the run's analysis points (0 = fully converged
    /// between analyses, 1 = every clause flipped every time).
    pub mean_dirty_fraction: f64,
    pub orderings: usize,
}

/// Stage the system for `figure` on one ordering.
pub fn configure(figure: Figure, seed: u64) -> Result<(SystemConfig, Vec<(usize, McuAction)>)> {
    let mut cfg = SystemConfig::paper();
    cfg.seed = seed;
    let mut schedule = Vec::new();
    match figure {
        Figure::Fig4 => {}
        Figure::Fig5 => {
            cfg.initial_filter = Some(0);
        }
        Figure::Fig6 => {
            cfg.initial_filter = Some(0);
            cfg.online_learning = false;
            // "introducing [the] new classification at runtime (after 5
            // online iterations)" — lift the filter before pass 6.
            schedule.push((6, McuAction::SetFilter { enabled: false, class: 0 }));
        }
        Figure::Fig7 => {
            cfg.initial_filter = Some(0);
            schedule.push((6, McuAction::SetFilter { enabled: false, class: 0 }));
        }
        Figure::Fig8 => {
            cfg.online_learning = false;
            let map = FaultMap::even_spread(&cfg.shape, 0.20, Fault::StuckAt0, seed ^ 0xF417)
                .context("fig8 fault map")?;
            schedule.push((6, McuAction::InjectFaults(map)));
        }
        Figure::Fig9 => {
            let map = FaultMap::even_spread(&cfg.shape, 0.20, Fault::StuckAt0, seed ^ 0xF417)
                .context("fig9 fault map")?;
            schedule.push((6, McuAction::InjectFaults(map)));
        }
    }
    Ok((cfg, schedule))
}

/// Run one figure over the cross-validation sweep.
pub fn run_figure(figure: Figure, opts: &SweepOptions) -> Result<FigureResult> {
    let orderings: Vec<Vec<usize>> =
        all_orderings(5).into_iter().take(opts.orderings.clamp(1, 120)).collect();
    let plan = BlockPlan::stratified(iris::booleanised(), 5, opts.seed)?;
    let blocks: Vec<BoolDataset> = (0..plan.n_blocks()).map(|i| plan.block(i).clone()).collect();

    let threads = if opts.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        opts.threads
    };

    // Fan orderings out over worker threads (the coordinator's event loop:
    // std threads + channels; tokio is not in this image's crate set).
    let (tx, rx) = mpsc::channel();
    let chunks: Vec<Vec<(usize, Vec<usize>)>> = {
        let mut cs: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); threads];
        for (i, ord) in orderings.iter().enumerate() {
            cs[i % threads].push((i, ord.clone()));
        }
        cs
    };
    std::thread::scope(|scope| {
        for chunk in &chunks {
            let tx = tx.clone();
            let blocks = &blocks;
            scope.spawn(move || {
                for (i, ord) in chunk {
                    let run = (|| -> Result<_> {
                        let (mut cfg, schedule) = configure(figure, opts.seed + *i as u64)?;
                        cfg.seed = opts.seed.wrapping_add(1000).wrapping_add(*i as u64);
                        let mut sys = FpgaSystem::new(cfg, blocks, ord)?;
                        for (it, action) in &schedule {
                            sys.mcu.schedule(*it, action.clone());
                        }
                        sys.run()
                    })();
                    // A closed receiver means the collector already bailed
                    // on an earlier error; stop producing, don't panic.
                    if tx.send((*i, run)).is_err() {
                        return;
                    }
                }
            });
        }
        drop(tx);
    });

    let mut runs: Vec<Option<crate::fpga::system::RunReport>> = (0..orderings.len())
        .map(|_| None)
        .collect();
    for (i, run) in rx {
        runs[i] = Some(run?);
    }
    let runs: Vec<_> = runs
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.with_context(|| format!("worker never reported ordering {i}")))
        .collect::<Result<_>>()?;

    let offline = Curve::aggregate(&runs.iter().map(|r| r.offline_curve.clone()).collect::<Vec<_>>());
    let validation =
        Curve::aggregate(&runs.iter().map(|r| r.validation_curve.clone()).collect::<Vec<_>>());
    let online = Curve::aggregate(&runs.iter().map(|r| r.online_curve.clone()).collect::<Vec<_>>());
    let n = runs.len() as f64;
    Ok(FigureResult {
        figure,
        offline,
        validation,
        online,
        mean_cycles: runs.iter().map(|r| r.total_cycles as f64).sum::<f64>() / n,
        mean_stall_cycles: runs.iter().map(|r| r.handshake.stall_cycles as f64).sum::<f64>() / n,
        mean_power_w: runs.iter().map(|r| r.power.total_w).sum::<f64>() / n,
        mean_dirty_fraction: runs.iter().map(|r| r.rescore.dirty_fraction()).sum::<f64>() / n,
        orderings: runs.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> SweepOptions {
        SweepOptions { orderings: 6, threads: 2, seed: 7 }
    }

    #[test]
    fn fig4_shape_online_and_validation_rise() {
        let r = run_figure(Figure::Fig4, &quick_opts()).unwrap();
        assert_eq!(r.offline.len(), 17);
        // The analysis points ran incrementally: the mean dirty fraction
        // is a real ratio, and converging runs leave clean clauses.
        assert!(
            (0.0..1.0).contains(&r.mean_dirty_fraction),
            "dirty fraction {}",
            r.mean_dirty_fraction
        );
        assert!(r.online.delta() > 0.05, "online delta {:.3}", r.online.delta());
        assert!(r.validation.delta() > 0.0, "val delta {:.3}", r.validation.delta());
        // Offline training set starts with the highest accuracy (§5.1).
        assert!(r.offline.mean_at(0) > r.validation.mean_at(0));
        assert!(r.offline.mean_at(0) > 0.7, "paper starts at 83%");
    }

    #[test]
    fn fig6_vs_fig7_class_introduction() {
        let base = run_figure(Figure::Fig6, &quick_opts()).unwrap();
        let online = run_figure(Figure::Fig7, &quick_opts()).unwrap();
        // Fig 6: accuracy falls when the class appears and stays low.
        let (at, drop) = base.validation.max_drop();
        assert_eq!(at, 6, "class appears in analysis 6 (introduced after 5 passes)");
        assert!(drop < -0.1, "visible drop, got {drop:.3}");
        let end_base = base.validation.mean_at(16);
        // Fig 7: recovery — final accuracy clearly above the frozen
        // baseline.
        let end_online = online.validation.mean_at(16);
        assert!(
            end_online > end_base + 0.05,
            "online {end_online:.3} vs frozen {end_base:.3}"
        );
    }

    #[test]
    fn fig8_vs_fig9_fault_recovery() {
        let frozen = run_figure(Figure::Fig8, &quick_opts()).unwrap();
        let online = run_figure(Figure::Fig9, &quick_opts()).unwrap();
        // Frozen system: the curve is exactly flat after the injection
        // (nothing can change a frozen machine) and not above the
        // pre-fault level. (Stuck-at-0 severity varies at 6 orderings;
        // the magnitude check lives in integration_figures at 12.)
        for it in 7..=16 {
            assert_eq!(
                frozen.online.mean_at(it),
                frozen.online.mean_at(6),
                "frozen after faults"
            );
        }
        // (Direction/magnitude of the fault drop is asserted at 12
        // orderings in integration_figures::fig8_faults_degrade_frozen_system;
        // at 6 orderings stuck-at-0 noise can mask it.)
        // Recovery: online learning ends above the frozen baseline.
        assert!(
            online.online.mean_at(16) > frozen.online.mean_at(16),
            "{:.3} !> {:.3}",
            online.online.mean_at(16),
            frozen.online.mean_at(16)
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_figure(Figure::Fig4, &quick_opts()).unwrap();
        let b = run_figure(Figure::Fig4, &quick_opts()).unwrap();
        for i in 0..a.offline.len() {
            assert_eq!(a.offline.mean_at(i), b.offline.mean_at(i));
            assert_eq!(a.online.mean_at(i), b.online.mean_at(i));
        }
    }

    #[test]
    fn figure_parse() {
        assert_eq!(Figure::parse("4").unwrap(), Figure::Fig4);
        assert_eq!(Figure::parse("fig9").unwrap(), Figure::Fig9);
        assert!(Figure::parse("10").is_err());
    }
}
