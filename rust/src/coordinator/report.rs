//! Result output: CSV files (one per figure, the series the paper plots)
//! and quick ASCII sparkline rendering for the terminal.

use crate::coordinator::experiment::FigureResult;
use crate::coordinator::metrics::Curve;
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// CSV for one figure: `iteration, <set>_mean, <set>_std ...` — exactly
/// the three series of the paper's plots plus error bars.
pub fn figure_csv(r: &FigureResult) -> String {
    let mut s = String::new();
    s.push_str(
        "iteration,offline_mean,offline_std,validation_mean,validation_std,online_mean,online_std\n",
    );
    for i in 0..r.offline.len() {
        let _ = writeln!(
            s,
            "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}",
            i,
            r.offline.points[i].mean,
            r.offline.points[i].std,
            r.validation.points[i].mean,
            r.validation.points[i].std,
            r.online.points[i].mean,
            r.online.points[i].std,
        );
    }
    s
}

/// Write a figure CSV into `dir`.
pub fn write_figure_csv(r: &FigureResult, dir: &Path) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(format!("{}.csv", r.figure.name()));
    std::fs::write(&path, figure_csv(r))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// ASCII sparkline of a curve (terminal feedback).
pub fn sparkline(c: &Curve) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let vals: Vec<f64> = c.points.iter().map(|p| p.mean).collect();
    let lo = vals.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-9);
    vals.iter()
        .map(|v| BARS[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

/// Terminal summary of one figure.
pub fn figure_summary(r: &FigureResult) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{} — {}", r.figure.name(), r.figure.title());
    let _ = writeln!(s, "  ({} orderings averaged)", r.orderings);
    for (name, c) in [
        ("offline ", &r.offline),
        ("validate", &r.validation),
        ("online  ", &r.online),
    ] {
        let _ = writeln!(
            s,
            "  {name}  start {:5.1}%  end {:5.1}%  Δ {:+5.1}%  {}",
            c.mean_at(0) * 100.0,
            c.mean_at(c.len() - 1) * 100.0,
            c.delta() * 100.0,
            sparkline(c)
        );
    }
    let _ = writeln!(
        s,
        "  mean cycles/run {:.0}  handshake stalls {:.0}  power {:.3} W  \
         rescore dirty {:.1}%",
        r.mean_cycles,
        r.mean_stall_cycles,
        r.mean_power_w,
        r.mean_dirty_fraction * 100.0
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::{run_figure, Figure, SweepOptions};
    use crate::coordinator::metrics::Curve;

    #[test]
    fn sparkline_shape() {
        let c = Curve::aggregate(&[vec![0.0, 0.5, 1.0]]);
        let s = sparkline(&c);
        assert_eq!(s.chars().count(), 3);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }

    #[test]
    fn csv_roundtrip_via_fs() {
        let opts = SweepOptions { orderings: 2, threads: 1, seed: 3 };
        let r = run_figure(Figure::Fig4, &opts).unwrap();
        let csv = figure_csv(&r);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + 17);
        assert!(lines[0].starts_with("iteration,offline_mean"));
        // Every data line has 7 comma-separated fields that parse.
        for l in &lines[1..] {
            let fields: Vec<&str> = l.split(',').collect();
            assert_eq!(fields.len(), 7);
            for f in &fields[1..] {
                f.parse::<f64>().unwrap();
            }
        }
        let dir = std::env::temp_dir().join("tmfpga_report_test");
        let path = write_figure_csv(&r, &dir).unwrap();
        assert!(path.exists());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn summary_contains_key_fields() {
        let opts = SweepOptions { orderings: 2, threads: 1, seed: 3 };
        let r = run_figure(Figure::Fig4, &opts).unwrap();
        let s = figure_summary(&r);
        assert!(s.contains("fig4"));
        assert!(s.contains("offline"));
        assert!(s.contains("power"));
    }
}
