//! Hyper-parameter search (§5 intro): "the fast execution time allows
//! entire datasets to be analyzed in a matter of seconds, allowing the
//! optimum hyper-parameters for a given dataset to be discovered within a
//! short period of time."
//!
//! Grid search over (s, T) with cross-validated validation accuracy as
//! the objective, fanned out across threads; each grid cell runs the
//! paper's offline-training flow on a subset of orderings.
//!
//! The folds are packed **and bitplane-transposed once** per ordering
//! ([`PackedSets`]) before the grid fan-out: every (s, T) cell shares the
//! same read-only folds and scores them through the sample-sliced
//! kernel ([`MultiTm::accuracy_planes`]), instead of re-deriving blocks,
//! re-packing rows and walking them one sample at a time per cell.

use crate::data::blocks::{all_orderings, BlockPlan, PackedSets, SetAllocation};
use crate::data::iris;
use crate::tm::bitplane::BitPlanes;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::Xoshiro256;
use crate::tm::train_planes::{train_rows_seq, TrainScratch};
use anyhow::Result;
use std::sync::mpsc;

/// One grid cell's outcome.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub s: f32,
    pub t: i32,
    pub val_accuracy: f64,
    pub train_accuracy: f64,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub s_grid: Vec<f32>,
    pub t_grid: Vec<i32>,
    pub orderings: usize,
    pub epochs: usize,
    pub threads: usize,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            s_grid: vec![1.0, 1.25, 1.375, 1.5, 2.0, 3.0, 4.0],
            t_grid: vec![4, 8, 15, 20],
            orderings: 12,
            epochs: 10,
            threads: 0,
            seed: 101,
        }
    }
}

/// Evaluate one (s, T) cell over pre-packed folds: offline-train on each
/// fold's offline rows, report mean validation accuracy. Scoring runs the
/// sample-sliced kernel off each fold's cached bitplanes.
pub fn evaluate_cell(
    shape: &TmShape,
    s: f32,
    t: i32,
    folds: &[PackedSets],
    epochs: usize,
    seed: u64,
) -> Result<SweepPoint> {
    let mut val_acc = 0.0;
    let mut train_acc = 0.0;
    for (i, fold) in folds.iter().enumerate() {
        // Paper §5.1: train on the first 20 of the 30-row offline set.
        let train = &fold.offline[..fold.offline.len().min(20)];
        let params = TmParams {
            s,
            t,
            active_clauses: shape.max_clauses,
            active_classes: shape.classes,
            boost_true_positive: false,
            s_style: crate::tm::params::SStyle::InactionBiased,
        };
        params.validate(shape)?;
        let mut tm = MultiTm::new(shape)?;
        let mut rng = Xoshiro256::new(seed.wrapping_add(i as u64));
        // Lane-speculative training: one transpose of the 20-row train
        // slice per fold, reused across every epoch of the cell —
        // bit-identical to the historical per-step refill loop.
        let mut scratch = TrainScratch::seeded(&mut rng, shape);
        let train_planes = BitPlanes::from_labelled(shape, train);
        for _ in 0..epochs {
            train_rows_seq(&mut tm, train, &train_planes, &params, &mut rng, &mut scratch);
        }
        val_acc += tm.accuracy_planes(&fold.validation_planes, &params);
        train_acc += tm.accuracy_planes(&fold.offline_planes, &params);
    }
    let n = folds.len() as f64;
    Ok(SweepPoint { s, t, val_accuracy: val_acc / n, train_accuracy: train_acc / n })
}

/// Run the full grid; results sorted by validation accuracy (best first).
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepPoint>> {
    let shape = TmShape::iris();
    let orderings: Vec<Vec<usize>> =
        all_orderings(5).into_iter().take(cfg.orderings.clamp(1, 120)).collect();
    // Pack + transpose each fold once, up front; every grid cell borrows
    // the same read-only folds.
    let plan = BlockPlan::stratified(iris::booleanised(), 5, cfg.seed)?;
    let folds: Vec<PackedSets> = orderings
        .iter()
        .map(|ord| Ok(plan.sets(ord, SetAllocation::paper())?.pack_planes(&shape)))
        .collect::<Result<_>>()?;
    let cells: Vec<(f32, i32)> = cfg
        .s_grid
        .iter()
        .flat_map(|&s| cfg.t_grid.iter().map(move |&t| (s, t)))
        .collect();

    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.threads
    };
    let (tx, rx) = mpsc::channel();
    std::thread::scope(|scope| {
        for w in 0..threads {
            let tx = tx.clone();
            let cells = &cells;
            let folds = &folds;
            let shape = &shape;
            scope.spawn(move || {
                for (i, (s, t)) in cells.iter().enumerate() {
                    if i % threads != w {
                        continue;
                    }
                    let r = evaluate_cell(shape, *s, *t, folds, cfg.epochs, cfg.seed);
                    // A closed receiver means the collector already bailed
                    // on an earlier error; stop producing, don't panic.
                    if tx.send(r).is_err() {
                        return;
                    }
                }
            });
        }
        drop(tx);
    });
    let mut points: Vec<SweepPoint> = rx.into_iter().collect::<Result<_>>()?;
    points.sort_by(|a, b| b.val_accuracy.total_cmp(&a.val_accuracy));
    Ok(points)
}

/// CSV rendering of the sweep surface.
pub fn sweep_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from("s,T,val_accuracy,train_accuracy\n");
    for p in points {
        s.push_str(&format!(
            "{},{},{:.6},{:.6}\n",
            p.s, p.t, p.val_accuracy, p.train_accuracy
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> SweepConfig {
        SweepConfig {
            s_grid: vec![1.375, 4.0],
            t_grid: vec![2, 15],
            orderings: 4,
            epochs: 5,
            threads: 2,
            seed: 5,
        }
    }

    #[test]
    fn sweep_covers_grid_and_sorts() {
        let pts = run_sweep(&quick()).unwrap();
        assert_eq!(pts.len(), 4);
        for w in pts.windows(2) {
            assert!(w[0].val_accuracy >= w[1].val_accuracy);
        }
        // Every accuracy sane.
        for p in &pts {
            assert!((0.0..=1.0).contains(&p.val_accuracy));
        }
    }

    #[test]
    fn paper_params_are_competitive() {
        // s=1.375, T=15 should beat a degenerate cell like T=2 at s=4.
        let pts = run_sweep(&quick()).unwrap();
        let paper = pts.iter().find(|p| p.s == 1.375 && p.t == 15).unwrap();
        assert!(paper.val_accuracy > 0.6, "paper cell works: {}", paper.val_accuracy);
    }

    #[test]
    fn csv_format() {
        let pts = vec![SweepPoint { s: 1.0, t: 15, val_accuracy: 0.8, train_accuracy: 0.9 }];
        let csv = sweep_csv(&pts);
        assert!(csv.starts_with("s,T,"));
        assert!(csv.contains("1,15,0.8"));
    }
}
