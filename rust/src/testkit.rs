//! Minimal property-testing kit (the offline image has no `proptest`).
//!
//! Deterministic, seeded case generation with failure reporting that
//! includes the per-case seed so any failing case can be replayed as a
//! unit test. Used by module tests across the crate for randomized
//! invariant checks.

use crate::tm::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 200, seed: 0x70_72_6F_70 } // "prop"
    }
}

/// Run `prop` on `cases` generated inputs. `gen` receives a fresh,
/// per-case-seeded RNG; `prop` returns `Err(msg)` to fail. Panics with
/// the case index and seed on the first failure.
pub fn check<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Xoshiro256) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (case_seed={case_seed:#x}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::tm::clause::Input;
    use crate::tm::machine::MultiTm;
    use crate::tm::params::TmShape;
    use crate::tm::rng::Xoshiro256;

    pub fn bool_vec(rng: &mut Xoshiro256, len: usize, p_true: f32) -> Vec<bool> {
        (0..len).map(|_| rng.next_f32() < p_true).collect()
    }

    /// One packed random input with p=0.5 feature density.
    pub fn input(rng: &mut Xoshiro256, shape: &TmShape) -> Input {
        Input::pack(shape, &bool_vec(rng, shape.features, 0.5))
    }

    /// `n` packed random inputs with p=0.5 feature density — the input
    /// half of every integration suite's dataset builder.
    pub fn inputs(rng: &mut Xoshiro256, shape: &TmShape, n: usize) -> Vec<Input> {
        (0..n).map(|_| input(rng, shape)).collect()
    }

    /// `n` labelled rows with uniformly random labels — the shared
    /// dataset builder for the engine/corpus suites.
    pub fn rows(rng: &mut Xoshiro256, shape: &TmShape, n: usize) -> Vec<(Input, usize)> {
        (0..n)
            .map(|_| {
                let x = Input::pack(shape, &bool_vec(rng, shape.features, 0.5));
                (x, rng.next_below(shape.classes))
            })
            .collect()
    }

    /// `n` labelled rows with cyclic labels (`i % classes`) — keeps every
    /// class represented even in tiny batches, as the plane-training
    /// suites require.
    pub fn rows_cyclic(rng: &mut Xoshiro256, shape: &TmShape, n: usize) -> Vec<(Input, usize)> {
        (0..n)
            .map(|i| {
                let x = Input::pack(shape, &bool_vec(rng, shape.features, 0.5));
                (x, i % shape.classes)
            })
            .collect()
    }

    /// Random machine with realistic include density: TA states drawn
    /// uniformly over the full `0..2·states` range. This is the one
    /// seeding path the serving/recovery suites share — it centralizes
    /// the `from_states(..)` boilerplate those tests used to hand-roll.
    pub fn machine(rng: &mut Xoshiro256, shape: &TmShape) -> MultiTm {
        let states: Vec<u32> = (0..shape.num_tas())
            .map(|_| rng.next_below(2 * shape.states as usize) as u32)
            .collect();
        MultiTm::from_states(shape, states)
            .expect("uniformly drawn TA states are always in range")
    }

    /// A random machine plus an independent clone — the oracle/subject
    /// pair every cross-engine equivalence test starts from.
    pub fn machine_pair(rng: &mut Xoshiro256, shape: &TmShape) -> (MultiTm, MultiTm) {
        let a = machine(rng, shape);
        let b = a.clone();
        (a, b)
    }

    pub fn usize_in(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
        lo + rng.next_below(hi - lo + 1)
    }

    pub fn f32_in(rng: &mut Xoshiro256, lo: f32, hi: f32) -> f32 {
        lo + rng.next_f32() * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            PropConfig { cases: 50, ..Default::default() },
            |rng| rng.next_below(100),
            |&x| if x < 100 { Ok(()) } else { Err("impossible".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            PropConfig { cases: 50, ..Default::default() },
            |rng| rng.next_below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }

    #[test]
    fn generators_in_range() {
        let mut rng = crate::tm::rng::Xoshiro256::new(1);
        for _ in 0..100 {
            let v = gen::usize_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&v));
            let f = gen::f32_in(&mut rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let bv = gen::bool_vec(&mut rng, 1000, 0.3);
        let ones = bv.iter().filter(|&&b| b).count();
        assert!((200..400).contains(&ones), "got {ones}");
    }
}
