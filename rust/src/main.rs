//! `tmfpga` — launcher for the FPGA online-learning TM reproduction.
//!
//! See `tmfpga help` (or [`tm_fpga::cli::USAGE`]) for the command set.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use tm_fpga::cli::{model_specs, serve_mode, validate_serve, Cli, UsageError, USAGE};
use tm_fpga::coordinator::{
    self, experiment::Figure, report, SweepConfig, SweepOptions,
};
use tm_fpga::data::{blocks::BlockPlan, iris};
use tm_fpga::fpga::system::{FpgaSystem, SystemConfig};
use tm_fpga::tm::{MultiTm, StepRands, TmParams, Xoshiro256};

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e:#}");
        if e.downcast_ref::<UsageError>().is_some() {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
        std::process::exit(1);
    }
}

fn dispatch(cli: &Cli) -> Result<()> {
    match cli.command.as_str() {
        "fig" => cmd_fig(cli),
        "run" => cmd_run(cli),
        "serve" => cmd_serve(cli),
        "perf" => cmd_perf(cli),
        "power" => cmd_power(),
        "sweep" => cmd_sweep(cli),
        "replay" => cmd_replay(cli),
        "parity" => cmd_parity(cli),
        "verify" => cmd_verify(cli),
        "explain" => cmd_explain(cli),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn sweep_opts(cli: &Cli) -> Result<SweepOptions> {
    Ok(SweepOptions {
        orderings: cli.flag_usize("orderings", 120)?,
        threads: cli.flag_usize("threads", 0)?,
        seed: cli.flag_u64("seed", 42)?,
    })
}

fn cmd_fig(cli: &Cli) -> Result<()> {
    let which = cli
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let figures: Vec<Figure> = if which == "all" {
        Figure::all().to_vec()
    } else {
        vec![Figure::parse(which)?]
    };
    let opts = sweep_opts(cli)?;
    let out: PathBuf = cli.flag("out").unwrap_or("results").into();
    for fig in figures {
        let t0 = std::time::Instant::now();
        let r = coordinator::run_figure(fig, &opts)?;
        print!("{}", report::figure_summary(&r));
        let path = report::write_figure_csv(&r, &out)?;
        println!("  wrote {}  ({:.1}s)\n", path.display(), t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_run(cli: &Cli) -> Result<()> {
    let mut cfg = SystemConfig::paper();
    cfg.online_iterations = cli.flag_usize("iterations", 16)?;
    cfg.online_learning = cli.flag_bool("online-learning", true)?;
    cfg.seed = cli.flag_u64("seed", 7)?;
    if let Some(c) = cli.flag("filter") {
        cfg.initial_filter = Some(c.parse()?);
    }
    let ordering = cli
        .flag_usize_list("ordering")?
        .unwrap_or_else(|| vec![0, 1, 2, 3, 4]);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, cfg.seed)?;
    let blocks: Vec<_> = (0..plan.n_blocks()).map(|i| plan.block(i).clone()).collect();
    let mut sys = FpgaSystem::new(cfg, &blocks, &ordering)?;
    let rep = sys.run()?;
    println!("UART log ({} reports):", rep.uart_log.len());
    for line in &rep.uart_log {
        println!("  {line}");
    }
    println!("\ntotal cycles      : {}", rep.total_cycles);
    println!(
        "handshake stalls  : {} cycles over {} transactions",
        rep.handshake.stall_cycles, rep.handshake.transactions
    );
    println!("dropped datapoints: {}", rep.dropped_datapoints);
    println!("TM toggle events  : {}", rep.tm_toggles);
    println!(
        "power             : {:.3} W total ({:.3} W MCU + {:.3} W fabric)",
        rep.power.total_w, rep.power.mcu_w, rep.power.fabric_w
    );
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    validate_serve(cli)?;
    // Redesigned subcommand modes; bare `serve` keeps the legacy
    // flag-selected behaviour below, unchanged.
    match serve_mode(cli)? {
        Some("soak") => return cmd_serve_hub(cli),
        Some("run") => return cmd_serve_listen(cli, false),
        Some("drill") => return cmd_serve_listen(cli, true),
        _ => {}
    }
    if cli.flag("net-chaos-seed").is_some() {
        return cmd_serve_net(cli);
    }
    if cli.flag("listen").is_some() {
        return cmd_serve_listen(cli, false);
    }
    // Flag fallbacks come from SoakConfig::default() so the CLI, the
    // soak driver and the help text cannot drift apart.
    let d = tm_fpga::coordinator::SoakConfig::default();
    let cfg = tm_fpga::coordinator::SoakConfig {
        shards: cli.flag_usize("shards", d.shards)?,
        events: cli.flag_usize("events", d.events)?,
        max_batch: cli.flag_usize("batch", d.max_batch)?,
        latency_budget: cli.flag_u64("deadline", d.latency_budget)?,
        labelled_fraction: cli.flag_f32("labelled", d.labelled_fraction)?,
        mean_gap: cli.flag_f64("gap", d.mean_gap)?,
        seed: cli.flag_u64("seed", d.seed)?,
        warmup_epochs: cli.flag_usize("warmup", d.warmup_epochs)?,
    };
    if cli.flag("chaos-seed").is_some() {
        return cmd_serve_chaos(cli, cfg);
    }
    let rep = coordinator::run_soak(&cfg)?;
    println!(
        "serving soak: {} events over {} shard(s) (batch cap {}, deadline {} ticks)",
        cfg.events, cfg.shards, cfg.max_batch, cfg.latency_budget
    );
    println!(
        "  inference requests : {} ({} responses)",
        rep.drive.infer_requests,
        rep.responses.len()
    );
    println!("  online updates     : {}", rep.drive.updates);
    println!(
        "  micro-batches      : {} ({} full / {} deadline / {} final), mean width {:.1}",
        rep.drive.batches,
        rep.drive.full_flushes,
        rep.drive.deadline_flushes,
        rep.drive.final_flushes,
        rep.drive.mean_batch_width()
    );
    for s in &rep.shards {
        println!(
            "  shard {}            : {} batches, {} samples, {} updates applied",
            s.shard, s.batches, s.samples, s.updates
        );
    }
    println!(
        "  throughput         : {:.0} samples/s ({:.3}s wall)",
        rep.samples_per_s(),
        rep.wall_s
    );
    if rep.agrees() {
        println!("  oracle check       : OK (bit-identical to the scalar MultiTm oracle)");
        Ok(())
    } else {
        bail!("{} responses diverged from the scalar oracle", rep.mismatches)
    }
}

fn cmd_serve_chaos(cli: &Cli, soak: tm_fpga::coordinator::SoakConfig) -> Result<()> {
    let d = tm_fpga::coordinator::ChaosSoakConfig::default();
    let cfg = tm_fpga::coordinator::ChaosSoakConfig {
        chaos_seed: cli.flag_u64("chaos-seed", d.chaos_seed)?,
        kills: cli.flag_usize("kills", d.kills)?,
        stalls: cli.flag_usize("stalls", d.stalls)?,
        corrupts: cli.flag_usize("corrupts", d.corrupts)?,
        malformed_every: cli.flag_usize("malformed-every", d.malformed_every)?,
        checkpoint_every: cli.flag_u64("checkpoint-every", d.checkpoint_every)?,
        recovery_lag: cli.flag_u64("recovery-lag", d.recovery_lag)?,
        degraded_depth: cli.flag_u64("degraded-depth", d.degraded_depth)?,
        soak,
    };
    let rep = coordinator::run_chaos_soak(&cfg)?;
    println!(
        "chaos soak: {} events over {} shard(s), seed {:#x}, {} scheduled fault(s), \
         checkpoint every {} update(s)",
        cfg.soak.events,
        cfg.soak.shards,
        cfg.chaos_seed,
        rep.plan.events.len(),
        cfg.checkpoint_every
    );
    println!(
        "  inference requests : {} ({} responses, {} shed, {} quarantined)",
        rep.drive.infer_requests,
        rep.responses.len(),
        rep.shed.len(),
        rep.drive.quarantined
    );
    println!("  online updates     : {}", rep.drive.updates);
    println!(
        "  chaos events       : {} fired / {} skipped (target already down)",
        rep.recovery.chaos_events_fired, rep.recovery.chaos_events_skipped
    );
    println!(
        "  worker panics      : {} ({} recoveries)",
        rep.recovery.worker_panics, rep.recovery.recoveries
    );
    println!(
        "  snapshots          : {} stored, {} rejected as corrupt",
        rep.recovery.snapshots_stored, rep.recovery.corrupt_snapshots_rejected
    );
    println!(
        "  replay             : {} updates replayed, {} batches re-dispatched",
        rep.recovery.replayed_updates, rep.recovery.redispatched_batches
    );
    println!("  wall               : {:.3}s", rep.wall_s);
    if rep.agrees() {
        println!(
            "  oracle check       : OK (post-recovery bit-identical to the scalar oracle, \
             shed/quarantine accounting exact)"
        );
        Ok(())
    } else {
        bail!(
            "chaos soak diverged: {} mismatches, replicas_match_oracle={}, accounting_exact={}",
            rep.mismatches,
            rep.replicas_match_oracle,
            rep.accounting_exact
        )
    }
}

fn cmd_serve_net(cli: &Cli) -> Result<()> {
    let d = tm_fpga::coordinator::NetSoakConfig::default();
    let cfg = tm_fpga::coordinator::NetSoakConfig {
        clients: cli.flag_usize("clients", d.clients)?,
        requests_per_client: cli.flag_u64("net-requests", d.requests_per_client)?,
        labelled_fraction: cli.flag_f32("labelled", d.labelled_fraction)?,
        seed: cli.flag_u64("seed", d.seed)?,
        net_chaos_seed: cli.flag_u64("net-chaos-seed", d.net_chaos_seed)?,
        shards: cli.flag_usize("shards", d.shards)?,
        max_batch: cli.flag_usize("batch", d.max_batch)?,
        latency_budget: cli.flag_u64("deadline", d.latency_budget)?,
        write_buffer_cap: cli.flag_u64("write-cap", d.write_buffer_cap)?,
        max_in_flight: cli.flag_u64("max-in-flight", d.max_in_flight)?,
        checkpoint_every: cli.flag_u64("checkpoint-every", d.checkpoint_every)?,
        ..d
    };
    let rep = coordinator::run_net_soak(&cfg)?;
    println!(
        "network chaos soak: {} client(s) × {} request(s), seed {:#x}, {} faulted client(s)",
        cfg.clients,
        cfg.requests_per_client,
        cfg.net_chaos_seed,
        rep.plan.faulted()
    );
    println!("  infers / learns    : {} / {}", rep.server.infers, rep.server.learns);
    println!("  preds              : {}", rep.server.preds);
    println!("  deadline expired   : {}", rep.server.deadline_expired);
    println!("  admission rejected : {}", rep.server.admission_rejected);
    println!("  slow-client shed   : {}", rep.server.shed_requests);
    println!("  quarantined        : {}", rep.server.quarantined);
    println!("  frame errors       : {}", rep.server.frame_errors);
    println!("  wall               : {:.3}s", rep.wall_s);
    if rep.agrees() {
        println!(
            "  oracle check       : OK (per-request outcomes, counters and final \
             replicas bit-identical)"
        );
        Ok(())
    } else {
        bail!(
            "network soak diverged: {} outcome mismatches, stats_match={}, \
             replicas_match={}, accounting_exact={}",
            rep.outcome_mismatches,
            rep.stats_match,
            rep.replicas_match,
            rep.accounting_exact
        )
    }
}

fn cmd_serve_listen(cli: &Cli, drill_mode: bool) -> Result<()> {
    use tm_fpga::hub::{HubConfig, ModelHub, SingleModel};
    use tm_fpga::net::{NetConfig, TcpTransport, PROTO_VERSION};
    // `serve run`/`serve drill` default the address; the legacy
    // spelling reaches here only with an explicit --listen.
    let addr = cli.flag("listen").unwrap_or("127.0.0.1:0");
    let seed = cli.flag_u64("seed", 42)?;
    let shards = cli.flag_usize("shards", 2)?;
    let shape = tm_fpga::tm::TmShape::iris();
    let params = TmParams::paper_online(&shape);
    let transport = TcpTransport::bind(addr)?;
    let bound = transport.local_addr();
    // Generous caps: on real sockets, frame debt includes
    // response-production lag, not just client slowness.
    let ncfg = NetConfig { max_in_flight: 4096, write_buffer_cap: 1024, ..Default::default() };
    // Drill request count: --requests (redesigned) or --drill N (legacy).
    let drill = if drill_mode || cli.flag("drill").is_some() {
        Some(cli.flag_u64("requests", cli.flag_u64("drill", 64)?)?)
    } else {
        None
    };
    let specs = model_specs(cli)?;
    if specs.is_empty() {
        // One anonymous default model on the sharded server.
        let mut rng = Xoshiro256::new(seed);
        let tm = tm_fpga::testkit::gen::machine(&mut rng, &shape);
        let scfg = tm_fpga::serve::ServeConfig::new(shards, params, seed);
        let server = tm_fpga::serve::ShardServer::new(&tm, &scfg)?;
        println!("serving on {bound} (protocol v{PROTO_VERSION}, {shards} shard(s))");
        drive_sockets(SingleModel(server), transport, &shape, ncfg, drill, seed)
    } else {
        // Named models in a hub, addressable via the wire `model=` field.
        let mut hub = ModelHub::new(HubConfig::default());
        for m in &specs {
            let mseed = m.seed.unwrap_or(seed);
            let mut rng = Xoshiro256::new(mseed);
            let tm = tm_fpga::testkit::gen::machine(&mut rng, &shape);
            hub.create(&m.name, tm, params.clone(), mseed)
                .map_err(|e| anyhow::anyhow!("registering model {}: {e}", m.name))?;
        }
        let names: Vec<&str> = specs.iter().map(|m| m.name.as_str()).collect();
        println!(
            "serving on {bound} (protocol v{PROTO_VERSION}, {} model(s): {})",
            specs.len(),
            names.join(", ")
        );
        drive_sockets(hub, transport, &shape, ncfg, drill, seed)
    }
}

/// Serve real sockets until drained, optionally racing an in-process
/// loopback drill client; shared by every backend flavour.
fn drive_sockets<B: tm_fpga::hub::HubNetBackend>(
    backend: B,
    transport: tm_fpga::net::TcpTransport,
    shape: &tm_fpga::tm::TmShape,
    ncfg: tm_fpga::net::NetConfig,
    drill: Option<u64>,
    seed: u64,
) -> Result<()> {
    use tm_fpga::net::{loopback_drill, run_tcp};
    let bound = transport.local_addr();
    if let Some(n) = drill {
        let features = shape.features;
        let client = std::thread::spawn(move || loopback_drill(bound, n, features, seed ^ 0xD8));
        let rep = run_tcp(backend, transport, shape, ncfg, Some(30_000))?;
        let drill = client.join().map_err(|_| anyhow::anyhow!("drill client panicked"))??;
        println!(
            "  drill client       : {} preds, {} errs, stats frame infers={}",
            drill.preds, drill.errs, drill.stats.infers
        );
        println!(
            "  server accounting  : {} infers, {} preds, {} frames in",
            rep.stats.infers, rep.stats.preds, rep.stats.frames_in
        );
        if drill.preds != n || drill.errs != 0 || rep.stats.infers != n {
            bail!("loopback drill lost responses: {}/{n} preds, {} errs", drill.preds, drill.errs);
        }
        println!("  drill              : OK (all {n} requests answered, graceful drain)");
        Ok(())
    } else {
        let rep = run_tcp(backend, transport, shape, ncfg, None)?;
        println!(
            "drained: {} infers, {} learns, {} preds, {} connection(s)",
            rep.stats.infers, rep.stats.learns, rep.stats.preds, rep.stats.connections
        );
        Ok(())
    }
}

fn cmd_serve_hub(cli: &Cli) -> Result<()> {
    if let Some(dir) = cli.flag("data-dir") {
        return cmd_serve_restart(cli, PathBuf::from(dir));
    }
    let d = tm_fpga::coordinator::HubSoakConfig::default();
    let specs = model_specs(cli)?;
    let tenants =
        if specs.is_empty() { cli.flag_usize("tenants", d.tenants)? } else { specs.len() };
    let cfg = tm_fpga::coordinator::HubSoakConfig {
        tenants,
        events_per_tenant: cli.flag_usize("events", d.events_per_tenant)?,
        rounds: cli.flag_usize("rounds", d.rounds)?,
        max_batch: cli.flag_usize("batch", d.max_batch)?,
        latency_budget: cli.flag_u64("deadline", d.latency_budget)?,
        labelled_fraction: cli.flag_f32("labelled", d.labelled_fraction)?,
        mean_gap: cli.flag_f64("gap", d.mean_gap)?,
        seed: cli.flag_u64("seed", d.seed)?,
        warmup_epochs: cli.flag_usize("warmup", d.warmup_epochs)?,
        budget_models: cli.flag_usize("budget-models", d.budget_models)?,
        checkpoint_every: cli.flag_u64("checkpoint-every", d.checkpoint_every)?,
        evict_period: cli.flag_usize("evict-every", d.evict_period)?,
        tenant_names: specs.iter().map(|m| m.name.clone()).collect(),
    };
    let rep = coordinator::run_hub_soak(&cfg)?;
    println!(
        "hub soak: {} tenant(s) × {} event(s) in {} round(s), budget {} replica(s), \
         forced evict every {} round(s)",
        cfg.tenants, cfg.events_per_tenant, cfg.rounds, cfg.budget_models, cfg.evict_period
    );
    for t in &rep.tenants {
        println!(
            "  {:<12} : {} responses, {} mismatch(es), stats {}, digest {}, \
             {} eviction(s) / {} rehydration(s)",
            t.name,
            t.responses,
            t.mismatches,
            if t.stats_match { "OK" } else { "DIVERGED" },
            if t.digest_match { "OK" } else { "DIVERGED" },
            t.evictions,
            t.rehydrations
        );
    }
    let (hits, misses) = rep.plane_cache;
    println!("  plane cache        : {hits} hit(s) / {misses} miss(es), shared across tenants");
    println!("  resident bytes     : {}", rep.resident_bytes);
    println!("  wall               : {:.3}s", rep.wall_s);
    if rep.agrees() {
        println!(
            "  oracle check       : OK (every tenant bit-identical to its private oracle \
             through eviction and rehydration)"
        );
        Ok(())
    } else {
        let diverged = rep
            .tenants
            .iter()
            .filter(|t| t.mismatches > 0 || !t.stats_match || !t.digest_match)
            .count();
        bail!("hub soak diverged for {diverged} tenant(s)")
    }
}

/// `serve soak --data-dir DIR`: one pass of the durable-hub restart
/// drill. Recovers whatever state a previous process left in DIR
/// (WAL + checkpoints), drives the per-tenant traces to completion, and
/// verifies answers and final digests bit-identical to the
/// never-crashed scalar oracle. With `--crash-after N` the Nth durable
/// write fail-stops the pass and the process exits 86 with DIR intact —
/// relaunching without the flag resumes from the crashed store, so the
/// two invocations together are a real kill-and-relaunch crash drill.
fn cmd_serve_restart(cli: &Cli, data_dir: PathBuf) -> Result<()> {
    let d = tm_fpga::coordinator::RestartSoakConfig::default();
    let specs = model_specs(cli)?;
    let tenants =
        if specs.is_empty() { cli.flag_usize("tenants", d.tenants)? } else { specs.len() };
    let cfg = tm_fpga::coordinator::RestartSoakConfig {
        tenants,
        events_per_tenant: cli.flag_usize("events", d.events_per_tenant)?,
        labelled_fraction: cli.flag_f32("labelled", d.labelled_fraction)?,
        mean_gap: cli.flag_f64("gap", d.mean_gap)?,
        seed: cli.flag_u64("seed", d.seed)?,
        warmup_epochs: cli.flag_usize("warmup", d.warmup_epochs)?,
        checkpoint_every: cli.flag_u64("checkpoint-every", d.checkpoint_every)?,
        evict_every: cli.flag_u64("evict-every", d.evict_every)?,
        segment_bytes: d.segment_bytes,
        data_dir,
        max_crash_points: d.max_crash_points,
        tenant_names: specs.iter().map(|m| m.name.clone()).collect(),
    };
    let crash_after = match cli.flag("crash-after") {
        Some(_) => Some(cli.flag_u64("crash-after", 1)?),
        None => None,
    };
    let run = coordinator::run_restart_once(&cfg, crash_after)?;
    println!(
        "durable soak: {} tenant(s) × {} event(s), store {}",
        cfg.tenants,
        cfg.events_per_tenant,
        cfg.data_dir.display()
    );
    if let Some(r) = &run.recovery {
        println!(
            "  recovery           : {} model(s) rebuilt, {} WAL record(s) replayed, \
             {} torn tail(s) truncated, {} stale manifest entr(y/ies)",
            r.models_recovered,
            r.wal_records_replayed,
            r.torn_tails_truncated,
            r.stale_manifest_entries
        );
    }
    println!("  answered           : {} inference(s) this pass", run.answered);
    if run.crashed {
        match crash_after {
            Some(n) => {
                eprintln!(
                    "  injected crash     : fail-stop at durable write {n}; store kept in {} \
                     (relaunch without --crash-after to resume)",
                    cfg.data_dir.display()
                );
                std::process::exit(86);
            }
            None => bail!(
                "durable soak hit a storage fail-stop; store kept in {}",
                cfg.data_dir.display()
            ),
        }
    }
    if run.divergences == 0 {
        println!(
            "  oracle check       : OK (answers and final digests bit-identical to the \
             never-crashed scalar oracle)"
        );
        Ok(())
    } else {
        bail!("durable soak diverged: {} mismatch(es) vs the scalar oracle", run.divergences)
    }
}

fn cmd_perf(cli: &Cli) -> Result<()> {
    let iters = cli.flag_usize("iters", 20)?;
    let pjrt_steps = cli.flag_usize("pjrt-steps", 60)?;
    let mut rows = vec![
        coordinator::fpga_model_row(),
        coordinator::engine_row(iters)?,
        coordinator::plane_infer_row(iters)?,
        coordinator::native_row(iters)?,
        coordinator::baseline_row(iters)?,
    ];
    match coordinator::pjrt_row(pjrt_steps)? {
        Some(r) => rows.push(r),
        None => eprintln!("(PJRT row skipped: run `make artifacts` first)"),
    }
    if let Some(r) = coordinator::pjrt_epoch_row(20)? {
        rows.push(r);
    }
    print!("{}", coordinator::perf_table(&rows));
    Ok(())
}

fn cmd_power() -> Result<()> {
    let rows = coordinator::power_table()?;
    print!("{}", coordinator::perf::power_table_text(&rows));
    println!("\npaper reference: 1.725 W total, 1.4 W microcontroller (§6)");
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    let cfg = SweepConfig {
        orderings: cli.flag_usize("orderings", 12)?,
        epochs: cli.flag_usize("epochs", 10)?,
        threads: cli.flag_usize("threads", 0)?,
        seed: cli.flag_u64("seed", 101)?,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let points = coordinator::run_sweep(&cfg)?;
    println!(
        "{} cells × {} orderings in {:.1}s (the paper's \"entire datasets \
         in a matter of seconds\")",
        points.len(),
        cfg.orderings,
        t0.elapsed().as_secs_f64()
    );
    println!("{:<8} {:<6} {:>10} {:>10}", "s", "T", "val acc", "train acc");
    for p in points.iter().take(10) {
        println!(
            "{:<8} {:<6} {:>9.1}% {:>9.1}%",
            p.s,
            p.t,
            p.val_accuracy * 100.0,
            p.train_accuracy * 100.0
        );
    }
    if let Some(dir) = cli.flag("out") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("sweep.csv");
        std::fs::write(&path, coordinator::sweep_csv(&points))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_replay(cli: &Cli) -> Result<()> {
    let interval = cli.flag_usize("interval", 5)?;
    let n = cli.flag_usize("orderings", 8)?;
    let orderings = tm_fpga::data::all_orderings(5);
    let mut plain = 0.0;
    let mut replay = 0.0;
    for (i, ord) in orderings.iter().take(n).enumerate() {
        let p = coordinator::run_with_replay(ord, 16, None, 40 + i as u64)?;
        let r = coordinator::run_with_replay(ord, 16, Some(interval), 40 + i as u64)?;
        plain += coordinator::retention(&p.offline_curve);
        replay += coordinator::retention(&r.offline_curve);
    }
    println!(
        "offline-set retention over {} orderings:\n  plain  : {:.1}%\n  replay : {:.1}% (1 offline row per {} online rows)",
        n,
        plain / n as f64 * 100.0,
        replay / n as f64 * 100.0,
        interval
    );
    Ok(())
}

fn cmd_explain(cli: &Cli) -> Result<()> {
    // Train the paper configuration on one ordering, then dump the clause
    // compositions and a per-datapoint vote attribution — the TM's
    // propositional interpretability in action.
    let shape = tm_fpga::tm::TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let seed = cli.flag_u64("seed", 7)?;
    let row: usize = cli.flag_usize("row", 0)?;
    let plan = BlockPlan::stratified(iris::booleanised(), 5, seed)?;
    let sets = plan.sets(&[0, 1, 2, 3, 4], tm_fpga::data::SetAllocation::paper())?;
    let train = sets.offline.pack(&shape);
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(seed);
    let mut rands = StepRands::draw(&mut rng, &shape);
    for _ in 0..10 {
        for (x, y) in &train {
            rands.refill(&mut rng, &shape);
            tm_fpga::tm::train_step(&mut tm, x, *y, &params, &rands);
        }
    }
    println!("clause compositions (trained on 30 iris rows, 10 epochs):");
    for d in tm_fpga::tm::explain::describe_machine(&tm, &params) {
        if !d.is_empty() {
            println!(
                "  class {} clause {:>2} [{}]  {}",
                d.class,
                d.clause,
                if d.polarity > 0 { "+" } else { "-" },
                d.expression()
            );
        }
    }
    let val = sets.validation.pack(&shape);
    let (x, y) = &val[row.min(val.len() - 1)];
    println!("\nattribution for validation row {row} (true class {y}):");
    print!("{}", tm_fpga::tm::explain::report(&mut tm, x, &params));
    Ok(())
}

fn cmd_verify(cli: &Cli) -> Result<()> {
    use tm_fpga::verify::{corpus, shrink};
    // Phase 1: replay every committed fixture through the five-lane
    // replayer; any divergence is a regression and fails the run.
    let fixtures: PathBuf = cli.flag("fixtures").unwrap_or("rust/tests/corpus").into();
    let mut replayed = 0usize;
    let mut checks = 0u64;
    if fixtures.is_dir() {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(&fixtures)
            .with_context(|| format!("reading {}", fixtures.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "ron"))
            .collect();
        paths.sort();
        for path in &paths {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            let sched = corpus::Schedule::parse(&text)
                .with_context(|| format!("parsing {}", path.display()))?;
            match corpus::replay(&sched) {
                Ok(rep) => {
                    replayed += 1;
                    checks += rep.checks;
                    println!(
                        "  {} : OK ({} steps, {} cross-checks)",
                        path.display(),
                        rep.steps,
                        rep.checks
                    );
                }
                Err(d) => bail!("fixture {} diverged at {d}", path.display()),
            }
        }
    }
    println!(
        "corpus replay: {replayed} fixture(s), {checks} cross-checks, \
         all engine pairs bit-identical"
    );
    // Phase 2 (optional): seeded corpus growth. Every divergence is
    // shrunk to a minimal schedule, written as a fixture, and fails the
    // run so CI turns it into a committed regression.
    let grow_n = cli.flag_usize("grow", 0)?;
    if grow_n > 0 {
        let steps = cli.flag_usize("steps", 100)?;
        let seed = cli.flag_u64("seed", 42)?;
        let out: PathBuf = cli.flag("out").unwrap_or("rust/tests/corpus").into();
        let shapes = [
            ("iris", tm_fpga::tm::TmShape::iris()),
            // A >64-feature shape so the multi-word tail-mask paths are
            // grown over too, not just iris's single-word planes.
            (
                "wide",
                tm_fpga::tm::TmShape { classes: 2, max_clauses: 8, features: 80, states: 50 },
            ),
        ];
        let mut found_any = false;
        for (name, shape) in &shapes {
            let t0 = std::time::Instant::now();
            let outcome = shrink::grow(shape, seed, grow_n, steps);
            println!(
                "corpus growth [{name}]: {} schedule(s), {} clean step(s), \
                 {} divergence(s) in {:.1}s",
                outcome.schedules,
                outcome.clean_steps,
                outcome.found.len(),
                t0.elapsed().as_secs_f64()
            );
            for r in &outcome.found {
                let fname = format!("repro_{name}_{seed:016x}_{}", r.found_at);
                let path = shrink::write_fixture(&out, &fname, &r.schedule)?;
                eprintln!(
                    "  reproducer ({} steps, from schedule {}): {}\n    wrote {}",
                    r.schedule.steps.len(),
                    r.found_at,
                    r.divergence,
                    path.display()
                );
                found_any = true;
            }
        }
        if found_any {
            bail!(
                "corpus growth found divergences; minimized fixtures written — \
                 fix the engines and commit them as regressions"
            );
        }
    }
    Ok(())
}

fn cmd_parity(cli: &Cli) -> Result<()> {
    let steps = cli.flag_usize("steps", 60)?;
    let dir = tm_fpga::runtime::default_artifacts_dir();
    if !dir.join("meta.json").exists() {
        bail!("artifacts not found in {} — run `make artifacts`", dir.display());
    }
    let client = tm_fpga::runtime::Client::cpu()?;
    let exe = tm_fpga::runtime::TmExecutor::load(&client, &dir)?;
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 7)?;
    let data = plan
        .sets(&[0, 1, 2, 3, 4], tm_fpga::data::SetAllocation::paper())?
        .offline
        .pack(&shape);
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(0xBEEF);
    let mut checked = 0usize;
    'outer: loop {
        for (x, y) in &data {
            let r = StepRands::draw(&mut rng, &shape);
            let pjrt = exe.train_step(&tm, x, *y, &params, &r)?;
            tm_fpga::tm::train_step(&mut tm, x, *y, &params, &r);
            if tm.ta().states() != &pjrt[..] {
                bail!("PARITY FAILURE at step {checked}");
            }
            checked += 1;
            if checked >= steps {
                break 'outer;
            }
        }
    }
    println!(
        "parity OK: {checked} training steps bit-identical between the \
         native rust path and the PJRT-executed Pallas/JAX artifact \
         (platform: {})",
        client.platform()
    );
    Ok(())
}
