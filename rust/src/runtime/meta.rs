//! Artifact metadata — the xla-free half of the runtime. Lives outside
//! the `pjrt` feature gate so artifact validation (and its tests in
//! `rust/tests/parity.rs`) run in every build.

use crate::runtime::json::Json;
use crate::tm::params::TmShape;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Structural metadata read from `meta.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub shape: TmShape,
    pub batch: usize,
    /// Scan length of the `tm_train_epoch` artifact (0 when absent —
    /// older artifact directories).
    pub epoch_steps: usize,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing meta.json")?;
        let s = j.get("shape")?;
        let shape = TmShape {
            classes: s.get("classes")?.as_usize()?,
            max_clauses: s.get("clauses")?.as_usize()?,
            features: s.get("features")?.as_usize()?,
            states: s.get("states")?.as_usize()? as u32,
        };
        shape.validate()?;
        let epoch_steps =
            j.get("epoch_steps").ok().and_then(|v| v.as_usize().ok()).unwrap_or(0);
        Ok(ArtifactMeta { shape, batch: j.get("batch")?.as_usize()?, epoch_steps })
    }
}

/// Default artifacts directory: `$TMFPGA_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("TMFPGA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
