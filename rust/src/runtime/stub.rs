//! Stub runtime used when the `pjrt` feature is off (the default: the
//! offline build image has no `xla` crate). Keeps every call site —
//! `coordinator::perf`, the CLI `parity` command, `rust/tests/parity.rs` —
//! compiling; all entry points fail with a clear message instead of
//! executing artifacts. The artifact-existence checks in those call sites
//! mean the stub is only ever reached when someone has artifacts on disk
//! but built without PJRT support.

use crate::runtime::meta::ArtifactMeta;
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` feature (requires the \
     external `xla` crate — see rust/src/runtime/mod.rs)";

/// Placeholder for the PJRT CPU client.
pub struct Client;

impl Client {
    pub fn cpu() -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}

/// Placeholder for a compiled artifact.
pub struct Executable;

/// Placeholder executor; `load` always fails (after validating the
/// metadata, so malformed artifact directories still error usefully).
pub struct TmExecutor {
    pub meta: ArtifactMeta,
}

impl TmExecutor {
    pub fn load(_client: &Client, dir: &Path) -> Result<Self> {
        let _ = ArtifactMeta::load(dir)?;
        bail!("{UNAVAILABLE}")
    }

    pub fn infer(
        &self,
        _tm: &MultiTm,
        _x: &Input,
        _params: &TmParams,
    ) -> Result<(Vec<i32>, usize)> {
        bail!("{UNAVAILABLE}")
    }

    pub fn train_step(
        &self,
        _tm: &MultiTm,
        _x: &Input,
        _target: usize,
        _params: &TmParams,
        _rands: &StepRands,
    ) -> Result<Vec<u32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn train_epoch(
        &self,
        _tm: &MultiTm,
        _steps: &[(Input, usize, StepRands)],
        _params: &TmParams,
    ) -> Result<Vec<u32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn eval_batch(
        &self,
        _tm: &MultiTm,
        _data: &[(Input, usize)],
        _params: &TmParams,
    ) -> Result<(Vec<i32>, usize)> {
        bail!("{UNAVAILABLE}")
    }

    pub fn accuracy(
        &self,
        _tm: &MultiTm,
        _data: &[(Input, usize)],
        _params: &TmParams,
    ) -> Result<f64> {
        bail!("{UNAVAILABLE}")
    }
}
