//! Minimal JSON parser for `artifacts/meta.json` (the offline image has no
//! serde). Supports the full JSON grammar minus exotic number forms; plenty
//! for the machine-generated metadata contract.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
            _ => bail!("not a non-negative integer"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            m.insert(key, self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = *self
                .bytes
                .get(self.pos)
                .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .bytes
                        .get(self.pos)
                        .ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("unknown escape \\{}", e as char),
                    }
                }
                _ => s.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_structure() {
        let j = Json::parse(
            r#"{"shape": {"classes": 3, "states": 100},
                "batch": 150,
                "artifacts": {"tm_infer": {"file": "tm_infer.hlo.txt",
                  "args": [{"shape": [3,16,32], "dtype": "int32"}]}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("shape").unwrap().get("classes").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("batch").unwrap().as_usize().unwrap(), 150);
        let args = j
            .get("artifacts")
            .unwrap()
            .get("tm_infer")
            .unwrap()
            .get("args")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(args[0].get("dtype").unwrap().as_str().unwrap(), "int32");
        let dims = args[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(dims.len(), 3);
        assert_eq!(dims[2].as_usize().unwrap(), 32);
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse(r#"["a", 1, false]"#).unwrap(),
            Json::Arr(vec![Json::Str("a".into()), Json::Num(1.0), Json::Bool(false)])
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(j, Json::Str("a\n\"b\"A".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }
}
