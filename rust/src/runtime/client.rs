//! PJRT client wrapper: loads HLO-text artifacts and compiles them once.
//!
//! This is the request-path bridge of the three-layer architecture: python
//! lowered the L2/L1 graph to `artifacts/*.hlo.txt` at build time; here the
//! `xla` crate's PJRT CPU client parses the text (the parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits — the reason text, not serialized
//! protos, is the interchange format) and compiles one executable per
//! artifact. After construction, no python is involved.

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// PJRT CPU client owning compiled executables.
pub struct Client {
    client: xla::PjRtClient,
}

impl Client {
    pub fn cpu() -> Result<Self> {
        Ok(Client { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned(),
        })
    }
}
