//! Marshaling between the crate's native types and XLA literals.
//!
//! Layouts follow the cross-layer contract (row-major `[classes, clauses,
//! literals]`, see `python/compile/model.py::example_args_*`). All
//! conversions are pure and unit-tested; the executor composes them.

use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use anyhow::Result;

/// TA states as an `i32[C, J, L]` literal.
pub fn state_literal(tm: &MultiTm) -> Result<xla::Literal> {
    let s = tm.shape();
    let v: Vec<i32> = tm.ta().states().iter().map(|&x| x as i32).collect();
    Ok(xla::Literal::vec1(&v).reshape(&[
        s.classes as i64,
        s.max_clauses as i64,
        s.literals() as i64,
    ])?)
}

/// Read TA states back out of an `i32[C, J, L]` literal.
pub fn states_from_literal(lit: &xla::Literal) -> Result<Vec<u32>> {
    Ok(lit.to_vec::<i32>()?.into_iter().map(|x| x as u32).collect())
}

/// A packed input row as an `f32[L]` literal.
pub fn input_literal(x: &Input) -> Result<xla::Literal> {
    let d = x.to_dense();
    Ok(xla::Literal::vec1(&d).reshape(&[d.len() as i64])?)
}

/// Fault gate masks as two `f32[C, J, L]` literals (AND, OR).
pub fn fault_literals(tm: &MultiTm) -> Result<(xla::Literal, xla::Literal)> {
    let s = tm.shape();
    let dims = [s.classes as i64, s.max_clauses as i64, s.literals() as i64];
    let (and_d, or_d) = tm.fault().to_dense();
    Ok((
        xla::Literal::vec1(&and_d).reshape(&dims)?,
        xla::Literal::vec1(&or_d).reshape(&dims)?,
    ))
}

/// Clause-number port as an `f32[J]` mask literal.
pub fn clause_mask_literal(tm: &MultiTm, params: &TmParams) -> Result<xla::Literal> {
    let s = tm.shape();
    let m: Vec<f32> = (0..s.max_clauses)
        .map(|j| if j < params.active_clauses { 1.0 } else { 0.0 })
        .collect();
    Ok(xla::Literal::vec1(&m).reshape(&[s.max_clauses as i64])?)
}

/// Active-class mask as an `f32[C]` literal.
pub fn class_mask_literal(tm: &MultiTm, params: &TmParams) -> Result<xla::Literal> {
    let s = tm.shape();
    let m: Vec<f32> = (0..s.classes)
        .map(|c| if c < params.active_classes { 1.0 } else { 0.0 })
        .collect();
    Ok(xla::Literal::vec1(&m).reshape(&[s.classes as i64])?)
}

/// Per-class feedback signs as an `f32[C]` literal.
pub fn sign_literal(signs: &[i8]) -> Result<xla::Literal> {
    let v: Vec<f32> = signs.iter().map(|&s| s as f32).collect();
    Ok(xla::Literal::vec1(&v).reshape(&[v.len() as i64])?)
}

/// Step randomness as (`f32[C, J]`, `f32[C, J, L]`) literals.
pub fn rand_literals(
    tm: &MultiTm,
    rands: &StepRands,
) -> Result<(xla::Literal, xla::Literal)> {
    let s = tm.shape();
    Ok((
        xla::Literal::vec1(&rands.clause_rand)
            .reshape(&[s.classes as i64, s.max_clauses as i64])?,
        xla::Literal::vec1(&rands.ta_rand).reshape(&[
            s.classes as i64,
            s.max_clauses as i64,
            s.literals() as i64,
        ])?,
    ))
}

/// Runtime hyper-parameter vector `[T, p_reinforce, p_weaken]` (f32[3]).
pub fn scalars_literal(params: &TmParams) -> Result<xla::Literal> {
    let v = [params.t as f32, params.p_reinforce(), params.p_weaken()];
    Ok(xla::Literal::vec1(&v).reshape(&[3])?)
}

/// Scalar T as `f32[]` (the infer/eval artifacts take it alone).
pub fn t_literal(params: &TmParams) -> xla::Literal {
    xla::Literal::scalar(params.t as f32)
}

/// A padded evaluation batch: `xs f32[B, L]`, `labels i32[B]`,
/// `valid f32[B]`.
pub fn batch_literals(
    data: &[(Input, usize)],
    batch: usize,
    literals: usize,
) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
    anyhow::ensure!(data.len() <= batch, "batch overflow: {} > {batch}", data.len());
    let mut xs = vec![0.0f32; batch * literals];
    let mut labels = vec![0i32; batch];
    let mut valid = vec![0.0f32; batch];
    for (i, (x, y)) in data.iter().enumerate() {
        xs[i * literals..(i + 1) * literals].copy_from_slice(&x.to_dense());
        labels[i] = *y as i32;
        valid[i] = 1.0;
    }
    Ok((
        xla::Literal::vec1(&xs).reshape(&[batch as i64, literals as i64])?,
        xla::Literal::vec1(&labels).reshape(&[batch as i64])?,
        xla::Literal::vec1(&valid).reshape(&[batch as i64])?,
    ))
}
