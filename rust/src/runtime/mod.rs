//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text) and executes them on the `xla` crate's CPU client. This is
//! the only place the L3 coordinator touches the L2/L1 graph; python never
//! runs on the request path.

pub mod bridge;
pub mod client;
pub mod executor;
pub mod json;

pub use client::{Client, Executable};
pub use executor::{default_artifacts_dir, ArtifactMeta, TmExecutor};
