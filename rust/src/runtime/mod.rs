//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text) and executes them on the `xla` crate's CPU client. This is
//! the only place the L3 coordinator touches the L2/L1 graph; python never
//! runs on the request path.
//!
//! The `xla` crate is not vendored into the offline build image, so the
//! executing half ([`bridge`], [`client`], [`executor`]) is gated behind
//! the `pjrt` feature. The default build uses [`stub`], whose entry points
//! fail with a clear message; artifact metadata parsing ([`meta`]) and the
//! JSON reader ([`json`]) are always available, so `meta.json` validation
//! and its tests run in every configuration.

pub mod json;
pub mod meta;

// Mechanical tripwire: the gated modules below `use xla::…`, which is not
// a declared dependency (the offline image doesn't carry it). Without
// this guard, `--features pjrt` dies with an opaque E0433 inside
// bridge.rs. To actually enable PJRT: add `xla` to [dependencies] in
// Cargo.toml and delete this compile_error.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the external `xla` crate: add it to \
     [dependencies] in Cargo.toml and remove this guard in \
     rust/src/runtime/mod.rs (the offline build image does not ship xla)"
);

#[cfg(feature = "pjrt")]
pub mod bridge;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use meta::{default_artifacts_dir, ArtifactMeta};

#[cfg(feature = "pjrt")]
pub use client::{Client, Executable};
#[cfg(feature = "pjrt")]
pub use executor::TmExecutor;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Client, Executable, TmExecutor};
