//! The TM executor: the PJRT-backed twin of the native `tm::MultiTm` path.
//!
//! Loads the three AOT artifacts (`tm_infer`, `tm_train`, `tm_eval_batch`)
//! described by `artifacts/meta.json`, validates the structural-shape
//! contract against the machine it is asked to run, and exposes typed
//! inference / training / batched-accuracy calls. Given identical
//! [`StepRands`] streams, `train_step` produces **bit-identical** TA states
//! to `tm::feedback::train_step` — asserted by `rust/tests/parity.rs`.

use crate::runtime::bridge;
use crate::runtime::client::{Client, Executable};
use crate::runtime::meta::ArtifactMeta;
use crate::tm::clause::Input;
use crate::tm::feedback::class_signs;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// PJRT-backed TM compute engine.
pub struct TmExecutor {
    pub meta: ArtifactMeta,
    infer: Executable,
    train: Executable,
    train_epoch: Option<Executable>,
    eval: Executable,
}

impl TmExecutor {
    /// Load and compile all artifacts from `dir`.
    pub fn load(client: &Client, dir: &Path) -> Result<Self> {
        let meta = ArtifactMeta::load(dir)?;
        let infer = client.load_hlo_text(&dir.join("tm_infer.hlo.txt"))?;
        let train = client.load_hlo_text(&dir.join("tm_train.hlo.txt"))?;
        let epoch_path = dir.join("tm_train_epoch.hlo.txt");
        let train_epoch = if meta.epoch_steps > 0 && epoch_path.exists() {
            Some(client.load_hlo_text(&epoch_path)?)
        } else {
            None
        };
        let eval = client.load_hlo_text(&dir.join("tm_eval_batch.hlo.txt"))?;
        Ok(TmExecutor { meta, infer, train, train_epoch, eval })
    }

    fn check_shape(&self, tm: &MultiTm) -> Result<()> {
        if tm.shape() != &self.meta.shape {
            bail!(
                "machine shape {:?} does not match artifact shape {:?} — re-run `make artifacts`",
                tm.shape(),
                self.meta.shape
            );
        }
        Ok(())
    }

    /// Single-datapoint inference via the AOT graph:
    /// (clamped class sums over *all* provisioned classes, prediction).
    pub fn infer(
        &self,
        tm: &MultiTm,
        x: &Input,
        params: &TmParams,
    ) -> Result<(Vec<i32>, usize)> {
        self.check_shape(tm)?;
        let (and_m, or_m) = bridge::fault_literals(tm)?;
        let inputs = [
            bridge::state_literal(tm)?,
            bridge::input_literal(x)?,
            and_m,
            or_m,
            bridge::clause_mask_literal(tm, params)?,
            bridge::class_mask_literal(tm, params)?,
            bridge::t_literal(params),
        ];
        let out = self.infer.run(&inputs)?;
        anyhow::ensure!(out.len() == 2, "tm_infer returns (sums, pred)");
        let sums = out[0].to_vec::<i32>()?;
        let pred = out[1].to_vec::<i32>()?[0] as usize;
        Ok((sums, pred))
    }

    /// One training step via the AOT graph; returns the new TA states
    /// (flat, row-major — same layout as `TaBlock::states`).
    pub fn train_step(
        &self,
        tm: &MultiTm,
        x: &Input,
        target: usize,
        params: &TmParams,
        rands: &StepRands,
    ) -> Result<Vec<u32>> {
        self.check_shape(tm)?;
        let shape = tm.shape();
        let signs = class_signs(target, rands, shape.classes, params.active_classes);
        let (and_m, or_m) = bridge::fault_literals(tm)?;
        let (clause_r, ta_r) = bridge::rand_literals(tm, rands)?;
        let inputs = [
            bridge::state_literal(tm)?,
            bridge::input_literal(x)?,
            bridge::sign_literal(&signs)?,
            clause_r,
            ta_r,
            and_m,
            or_m,
            bridge::clause_mask_literal(tm, params)?,
            bridge::class_mask_literal(tm, params)?,
            bridge::scalars_literal(params)?,
        ];
        let out = self.train.run(&inputs)?;
        anyhow::ensure!(out.len() == 1, "tm_train returns (new_state,)");
        bridge::states_from_literal(&out[0])
    }

    /// A whole training pass in ONE dispatch via the scan artifact
    /// (`tm_train_epoch`): `steps[i] = (input, target, rands)`. Passes
    /// shorter than the artifact's scan length are padded with all-zero
    /// sign vectors (provable no-op steps). Returns the final TA states.
    pub fn train_epoch(
        &self,
        tm: &MultiTm,
        steps: &[(Input, usize, StepRands)],
        params: &TmParams,
    ) -> Result<Vec<u32>> {
        self.check_shape(tm)?;
        let exe = self
            .train_epoch
            .as_ref()
            .context("artifacts lack tm_train_epoch — re-run `make artifacts`")?;
        let n = self.meta.epoch_steps;
        anyhow::ensure!(
            steps.len() <= n,
            "pass of {} steps exceeds the artifact's scan length {n}",
            steps.len()
        );
        let shape = tm.shape();
        let (c, j, l) = (shape.classes, shape.max_clauses, shape.literals());
        let mut xs = vec![0.0f32; n * l];
        let mut signs = vec![0.0f32; n * c];
        let mut clause_rands = vec![0.0f32; n * c * j];
        let mut ta_rands = vec![0.0f32; n * c * j * l];
        for (i, (x, target, rands)) in steps.iter().enumerate() {
            xs[i * l..(i + 1) * l].copy_from_slice(&x.to_dense());
            let s = class_signs(*target, rands, c, params.active_classes);
            for (k, &sv) in s.iter().enumerate() {
                signs[i * c + k] = sv as f32;
            }
            clause_rands[i * c * j..(i + 1) * c * j].copy_from_slice(&rands.clause_rand);
            ta_rands[i * c * j * l..(i + 1) * c * j * l].copy_from_slice(&rands.ta_rand);
        }
        let (and_m, or_m) = bridge::fault_literals(tm)?;
        let inputs = [
            bridge::state_literal(tm)?,
            xla::Literal::vec1(&xs).reshape(&[n as i64, l as i64])?,
            xla::Literal::vec1(&signs).reshape(&[n as i64, c as i64])?,
            xla::Literal::vec1(&clause_rands).reshape(&[n as i64, c as i64, j as i64])?,
            xla::Literal::vec1(&ta_rands)
                .reshape(&[n as i64, c as i64, j as i64, l as i64])?,
            and_m,
            or_m,
            bridge::clause_mask_literal(tm, params)?,
            bridge::class_mask_literal(tm, params)?,
            bridge::scalars_literal(params)?,
        ];
        let out = exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 1, "tm_train_epoch returns (state,)");
        bridge::states_from_literal(&out[0])
    }

    /// Batched accuracy analysis via the AOT graph: (predictions for the
    /// first `data.len()` rows, correct count).
    pub fn eval_batch(
        &self,
        tm: &MultiTm,
        data: &[(Input, usize)],
        params: &TmParams,
    ) -> Result<(Vec<i32>, usize)> {
        self.check_shape(tm)?;
        let shape = tm.shape();
        let (xs, labels, valid) =
            bridge::batch_literals(data, self.meta.batch, shape.literals())?;
        let (and_m, or_m) = bridge::fault_literals(tm)?;
        let inputs = [
            bridge::state_literal(tm)?,
            xs,
            labels,
            valid,
            and_m,
            or_m,
            bridge::clause_mask_literal(tm, params)?,
            bridge::class_mask_literal(tm, params)?,
            bridge::t_literal(params),
        ];
        let out = self.eval.run(&inputs)?;
        anyhow::ensure!(out.len() == 2, "tm_eval_batch returns (preds, correct)");
        let preds = out[0].to_vec::<i32>()?[..data.len()].to_vec();
        let correct = out[1].to_vec::<i32>()?[0] as usize;
        Ok((preds, correct))
    }

    /// Accuracy via the batched artifact.
    pub fn accuracy(
        &self,
        tm: &MultiTm,
        data: &[(Input, usize)],
        params: &TmParams,
    ) -> Result<f64> {
        if data.is_empty() {
            return Ok(0.0);
        }
        // Chunk through the padded batch size.
        let mut correct = 0usize;
        for chunk in data.chunks(self.meta.batch) {
            correct += self.eval_batch(tm, chunk, params)?.1;
        }
        Ok(correct as f64 / data.len() as f64)
    }
}
