//! # tm-fpga
//!
//! Reproduction of *"An FPGA Architecture for Online Learning using the
//! Tsetlin Machine"* (Prescott, Wheeldon, Shafik, Rahman, Yakovlev &
//! Granmo, 2023) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! - **L3** (this crate): the paper's online-learning management
//!   architecture — both a cycle-level RTL simulator ([`fpga`]) of the
//!   FPGA design and a behavioural fast path ([`tm`] + [`coordinator`])
//!   used for cross-validated experiment sweeps.
//! - **L2/L1** (`python/compile/`, build time only): the TM inference and
//!   training step in JAX calling Pallas kernels, AOT-lowered to HLO text
//!   in `artifacts/` and executed from Rust via [`runtime`] (PJRT CPU).
//!
//! See `DESIGN.md` for the system inventory and per-experiment index.

pub mod baseline;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod fpga;
pub mod hub;
pub mod net;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod testkit;
pub mod tm;
pub mod util;
pub mod verify;
