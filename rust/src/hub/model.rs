//! The [`ModelHub`]: handle-based ownership, LRU residency under a
//! memory budget, and bit-identical eviction/rehydration.
//!
//! Every tenant model is one [`ModelEntry`]: its run-time params, a
//! per-model `base_seed`, a monotone per-model update `seq`, a TMFS v2
//! checkpoint ([`crate::serve::snapshot_bytes`]) taken at
//! `checkpoint_seq`, and the retained log suffix `(checkpoint_seq,
//! seq]`. A *hot* entry additionally holds the live machine; a *cold*
//! one holds only checkpoint + log. Because all `Learn` randomness is
//! keyed `(base_seed, seq)` (`crate::tm::update`), rehydration —
//! restore the checkpoint, replay the retained suffix — reconstructs
//! the machine bit-identically no matter when or how often the model
//! was evicted in between. That determinism argument is proven per
//! shard by the supervisor's crash recovery; the hub reuses it verbatim
//! for memory management.

use crate::hub::cache::PlaneCache;
use crate::serve::{restore, snapshot_bytes};
use crate::store::{RecoveredModel, Store, StoreError, WalOp};
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::StepRands;
use crate::tm::update::{ShardUpdate, UpdateKind};

use std::collections::BTreeMap;

/// Opaque handle to a hub-owned model. The id inside is stable for the
/// hub's lifetime and doubles as the wire-protocol model id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ModelHandle {
    id: u64,
}

impl ModelHandle {
    /// The routable model id (wire `model` dimension, telemetry key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Rebuild a handle from a routed id. Requests carrying a stale or
    /// forged id fail typed at the next hub call, so this is safe to
    /// expose to the routing layer.
    pub fn from_id(id: u64) -> Self {
        ModelHandle { id }
    }
}

/// Hub-wide policy knobs.
#[derive(Debug, Clone)]
pub struct HubConfig {
    /// Upper bound on resident (hot) model state, in bytes — the
    /// checkpoint encoding is the accounting unit, so the bound is a
    /// deterministic function of model shapes. `0` = unlimited.
    pub memory_budget: usize,
    /// Refresh a model's checkpoint every N updates, bounding the
    /// retained log (and thus rehydration replay cost). `0` disables
    /// refresh: the creation-time checkpoint plus the full log is kept.
    pub checkpoint_every: u64,
    /// Distinct input batches the shared bitplane cache retains.
    pub plane_cache_batches: usize,
}

impl Default for HubConfig {
    fn default() -> Self {
        HubConfig { memory_budget: 0, checkpoint_every: 64, plane_cache_batches: 64 }
    }
}

/// Typed hub failure. Nothing in the hub drops work silently: every
/// refusal names its cause.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HubError {
    /// No model under that name/handle.
    UnknownModel(String),
    /// The model is mid-eviction; retry after the barrier completes.
    Evicting { model: u64 },
    /// Making the model resident would exceed the memory budget and no
    /// other replica is evictable.
    BudgetExhausted { need: usize, resident: usize, budget: usize },
    /// Model names are 1..=32 chars of `[A-Za-z0-9_-]`.
    BadName(String),
    /// The name is already bound.
    DuplicateName(String),
    /// A checkpoint failed to restore — an invariant break, surfaced
    /// typed instead of panicking in the serving loop.
    Corrupt { model: u64, detail: String },
    /// The durable store refused a write (I/O error, disk full, or an
    /// earlier failure poisoned it). Write-ahead ordering means the
    /// refused mutation did **not** take effect in memory; the store is
    /// fail-stop, so every later durable mutation also refuses typed.
    Storage { detail: String },
}

impl std::fmt::Display for HubError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HubError::UnknownModel(name) => write!(f, "hub: unknown model {name}"),
            HubError::Evicting { model } => write!(f, "hub: model {model} is evicting"),
            HubError::BudgetExhausted { need, resident, budget } => write!(
                f,
                "hub: memory budget exhausted ({need} bytes needed, {resident} resident, \
                 {budget} budget, nothing evictable)"
            ),
            HubError::BadName(name) => {
                write!(f, "hub: bad model name {name:?} (want 1..=32 of [A-Za-z0-9_-])")
            }
            HubError::DuplicateName(name) => write!(f, "hub: model {name} already exists"),
            HubError::Corrupt { model, detail } => {
                write!(f, "hub: model {model} checkpoint corrupt: {detail}")
            }
            HubError::Storage { detail } => write!(f, "hub: durable store: {detail}"),
        }
    }
}

impl std::error::Error for HubError {}

impl From<StoreError> for HubError {
    fn from(e: StoreError) -> HubError {
        HubError::Storage { detail: e.to_string() }
    }
}

/// A valid hub/wire model name: 1..=32 chars of `[A-Za-z0-9_-]`. The
/// same grammar the wire protocol enforces on `model=` fields, kept
/// dependency-free here so the hub never imports the net layer.
pub fn valid_model_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 32
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
}

/// Lower an update to its durable wire form. The WAL stores raw
/// feature bits, not packed literal words: `Input::pack` is a pure
/// function of the bits, so the round trip is exact and the log format
/// stays independent of the literal packing.
fn to_wal_op(shape: &TmShape, kind: &UpdateKind) -> WalOp {
    match kind {
        UpdateKind::Learn { input, label } => WalOp::Learn {
            label: *label as u32,
            bits: (0..shape.features).map(|k| input.literal(k)).collect(),
        },
        UpdateKind::ClauseFault { class, clause, force } => WalOp::ClauseFault {
            class: *class as u32,
            clause: *clause as u32,
            force: *force,
        },
    }
}

/// Lift a replayed WAL op back into an update. CRC framing already
/// vouches for the bytes; these bounds checks keep a logically
/// impossible record (wrong width, out-of-range label) a typed error
/// instead of a panic in the replay loop.
fn from_wal_op(shape: &TmShape, model: u64, op: &WalOp) -> Result<UpdateKind, HubError> {
    let corrupt = |detail: String| HubError::Corrupt { model, detail };
    match op {
        WalOp::Learn { label, bits } => {
            if bits.len() != shape.features {
                return Err(corrupt(format!(
                    "logged sample has {} features, model has {}",
                    bits.len(),
                    shape.features
                )));
            }
            let label = *label as usize;
            if label >= shape.classes {
                return Err(corrupt(format!(
                    "logged label {label} out of range for {} classes",
                    shape.classes
                )));
            }
            Ok(UpdateKind::Learn { input: Input::pack(shape, bits), label })
        }
        WalOp::ClauseFault { class, clause, force } => {
            let (class, clause) = (*class as usize, *clause as usize);
            if class >= shape.classes || clause >= shape.max_clauses {
                return Err(corrupt(format!(
                    "logged clause fault ({class}, {clause}) outside shape"
                )));
            }
            Ok(UpdateKind::ClauseFault { class, clause, force: *force })
        }
    }
}

/// Durable write-through at an eviction boundary: publish a checkpoint
/// at the entry's current seq (making the retained WAL suffix
/// obsolete), then fold it into the in-memory entry so the next
/// rehydration replays nothing. No-op without a store, and skipped
/// when the newest checkpoint is already current.
fn write_through(
    store: Option<&mut Store>,
    id: u64,
    entry: &mut ModelEntry,
) -> Result<(), HubError> {
    let Some(store) = store else { return Ok(()) };
    if entry.log.is_empty() && entry.checkpoint_seq == entry.seq {
        return Ok(());
    }
    let machine = match &entry.state {
        Residency::Hot(m) | Residency::Evicting(m) => m,
        Residency::Cold => return Ok(()),
    };
    let bytes = snapshot_bytes(machine, &entry.params, entry.seq);
    store.publish_checkpoint(id, entry.seq, &bytes)?;
    entry.checkpoint = bytes;
    entry.checkpoint_seq = entry.seq;
    entry.log.clear();
    entry.cost = entry.checkpoint.len();
    Ok(())
}

/// Where a model's machine currently lives.
enum Residency {
    /// Live machine, servable.
    Hot(Box<MultiTm>),
    /// Mid-eviction barrier: the machine is still resident (so the
    /// budget still counts it) but requests are refused typed until
    /// [`ModelHub::finish_evict`] completes the transition.
    Evicting(Box<MultiTm>),
    /// Only checkpoint + retained log remain; the next request
    /// rehydrates.
    Cold,
}

struct ModelEntry {
    name: String,
    shape: TmShape,
    params: TmParams,
    base_seed: u64,
    /// Last applied update seq (the per-model log clock).
    seq: u64,
    /// TMFS v2 bytes capturing the machine at `checkpoint_seq`.
    checkpoint: Vec<u8>,
    checkpoint_seq: u64,
    /// Retained updates `(checkpoint_seq, seq]`, replayed on rehydrate.
    log: Vec<ShardUpdate>,
    /// Resident cost in bytes (= checkpoint length, a deterministic
    /// shape-derived proxy for the live machine's footprint).
    cost: usize,
    evictions: u64,
    rehydrations: u64,
    scratch: Option<StepRands>,
    state: Residency,
}

/// Owns many served models behind opaque handles; see the module docs.
pub struct ModelHub {
    cfg: HubConfig,
    entries: BTreeMap<u64, ModelEntry>,
    names: BTreeMap<String, u64>,
    /// Touch order, oldest first. Contains every model id; eviction
    /// scans for the coldest *hot* one.
    lru: Vec<u64>,
    next_id: u64,
    default_model: Option<u64>,
    pub(crate) planes: PlaneCache,
    /// Streamed `(request id, class)` responses for the net backend.
    pub(crate) responses: Vec<(u64, usize)>,
    pub(crate) polled: usize,
    /// Durable persistence, when attached: every create/update is
    /// WAL-logged write-ahead and checkpoint refreshes publish to disk.
    store: Option<Store>,
}

impl ModelHub {
    pub fn new(cfg: HubConfig) -> Self {
        let plane_cap = cfg.plane_cache_batches;
        ModelHub {
            cfg,
            entries: BTreeMap::new(),
            names: BTreeMap::new(),
            lru: Vec::new(),
            next_id: 0,
            default_model: None,
            planes: PlaneCache::new(plane_cap),
            responses: Vec::new(),
            polled: 0,
            store: None,
        }
    }

    /// Open a durable hub over a [`Store`]: every model recorded on
    /// disk is rebuilt (checkpoint restore + keyed WAL-suffix replay on
    /// first touch) and every future create/update writes through. A
    /// fresh data directory yields an empty hub. Because all `Learn`
    /// randomness is keyed `(base_seed, seq)`, the rebuilt hub is
    /// bit-identical to one that never went down — the restart soak
    /// (`coordinator::soak`) pins exactly that.
    pub fn open_durable(
        cfg: HubConfig,
        store: Store,
        recovered: Vec<RecoveredModel>,
    ) -> Result<Self, HubError> {
        let mut hub = ModelHub::new(cfg);
        let mut recovered = recovered;
        recovered.sort_by_key(|m| m.id);
        for m in recovered {
            let snap = restore(&m.ckpt_bytes)
                .map_err(|e| HubError::Corrupt { model: m.id, detail: format!("{e:#}") })?;
            if snap.seq != m.ckpt_seq {
                return Err(HubError::Corrupt {
                    model: m.id,
                    detail: format!(
                        "checkpoint seq {} disagrees with manifest seq {}",
                        snap.seq, m.ckpt_seq
                    ),
                });
            }
            let shape = snap.machine.shape().clone();
            let mut seq = m.ckpt_seq;
            let mut log = Vec::with_capacity(m.ops.len());
            for (s, op) in &m.ops {
                // The store already proved contiguity; keep the hub
                // paranoid about its only rebuild input.
                if *s != seq + 1 {
                    return Err(HubError::Corrupt {
                        model: m.id,
                        detail: format!("log suffix jumps from seq {seq} to {s}"),
                    });
                }
                seq = *s;
                log.push(ShardUpdate { seq, kind: from_wal_op(&shape, m.id, op)? });
            }
            let cost = m.ckpt_bytes.len();
            hub.names.insert(m.name.clone(), m.id);
            hub.lru.push(m.id);
            hub.entries.insert(
                m.id,
                ModelEntry {
                    name: m.name,
                    shape,
                    params: snap.params,
                    base_seed: m.base_seed,
                    seq,
                    checkpoint: m.ckpt_bytes,
                    checkpoint_seq: m.ckpt_seq,
                    log,
                    cost,
                    evictions: 0,
                    rehydrations: 0,
                    scratch: None,
                    state: Residency::Cold,
                },
            );
            if hub.default_model.is_none() {
                hub.default_model = Some(m.id);
            }
            hub.next_id = hub.next_id.max(m.id + 1);
        }
        hub.store = Some(store);
        Ok(hub)
    }

    /// The attached durable store (recovery report, write counters),
    /// if any.
    pub fn store(&self) -> Option<&Store> {
        self.store.as_ref()
    }

    /// Flush WAL appends the sync policy deferred. No-op for an
    /// in-memory hub.
    pub fn sync_durable(&mut self) -> Result<(), HubError> {
        match self.store.as_mut() {
            Some(store) => store.sync().map_err(HubError::from),
            None => Ok(()),
        }
    }

    /// A model's last applied update seq — the resume point a restarted
    /// driver continues its trace from.
    pub fn model_seq(&self, h: ModelHandle) -> Option<u64> {
        self.entries.get(&h.id).map(|e| e.seq)
    }

    /// Register a model under `name`. The first created model becomes
    /// the hub's default (what model-less wire frames route to). The
    /// machine is checkpointed at seq 0 immediately, so eviction is
    /// possible from the first tick.
    pub fn create(
        &mut self,
        name: &str,
        machine: MultiTm,
        params: TmParams,
        base_seed: u64,
    ) -> Result<ModelHandle, HubError> {
        if !valid_model_name(name) {
            return Err(HubError::BadName(name.to_string()));
        }
        if self.names.contains_key(name) {
            return Err(HubError::DuplicateName(name.to_string()));
        }
        let shape = machine.shape().clone();
        let checkpoint = snapshot_bytes(&machine, &params, 0);
        let cost = checkpoint.len();
        if self.cfg.memory_budget > 0 && cost > self.cfg.memory_budget {
            return Err(HubError::BudgetExhausted {
                need: cost,
                resident: self.resident_bytes(),
                budget: self.cfg.memory_budget,
            });
        }
        self.make_room(cost, u64::MAX)?;
        let id = self.next_id;
        // Write-ahead: the birth (Create record + genesis checkpoint +
        // manifest row) must be durable before the model exists in
        // memory — a refused create leaves no trace on either side.
        if let Some(store) = self.store.as_mut() {
            store.log_create(id, name, base_seed, &checkpoint)?;
        }
        self.next_id += 1;
        self.entries.insert(
            id,
            ModelEntry {
                name: name.to_string(),
                shape,
                params,
                base_seed,
                seq: 0,
                checkpoint,
                checkpoint_seq: 0,
                log: Vec::new(),
                cost,
                evictions: 0,
                rehydrations: 0,
                scratch: None,
                state: Residency::Hot(Box::new(machine)),
            },
        );
        self.names.insert(name.to_string(), id);
        self.lru.push(id);
        if self.default_model.is_none() {
            self.default_model = Some(id);
        }
        Ok(ModelHandle { id })
    }

    /// Handle for a model by name.
    pub fn resolve(&self, name: &str) -> Option<ModelHandle> {
        self.names.get(name).map(|&id| ModelHandle { id })
    }

    /// The default model (first created), if any.
    pub fn default_handle(&self) -> Option<ModelHandle> {
        self.default_model.map(|id| ModelHandle { id })
    }

    /// Every model handle, ascending by id.
    pub fn handles(&self) -> Vec<ModelHandle> {
        self.entries.keys().map(|&id| ModelHandle { id }).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes of resident model state (hot + mid-eviction replicas).
    pub fn resident_bytes(&self) -> usize {
        self.entries
            .values()
            .filter(|e| !matches!(e.state, Residency::Cold))
            .map(|e| e.cost)
            .sum()
    }

    /// True when the model's machine is live (servable without
    /// rehydration).
    pub fn is_hot(&self, h: ModelHandle) -> bool {
        matches!(self.entries.get(&h.id).map(|e| &e.state), Some(Residency::Hot(_)))
    }

    /// `(evictions, rehydrations)` for one model.
    pub fn lifecycle(&self, h: ModelHandle) -> (u64, u64) {
        self.entries.get(&h.id).map(|e| (e.evictions, e.rehydrations)).unwrap_or((0, 0))
    }

    /// Shared bitplane-cache `(hits, misses)`.
    pub fn plane_cache_stats(&self) -> (u64, u64) {
        self.planes.stats()
    }

    /// The name a model was registered under.
    pub fn name(&self, h: ModelHandle) -> Option<&str> {
        self.entries.get(&h.id).map(|e| e.name.as_str())
    }

    /// The shape a model serves.
    pub fn shape_of(&self, h: ModelHandle) -> Option<&TmShape> {
        self.entries.get(&h.id).map(|e| &e.shape)
    }

    /// Updates retained since the model's last checkpoint (replay cost
    /// of the next rehydration).
    pub fn retained_log_len(&self, h: ModelHandle) -> usize {
        self.entries.get(&h.id).map(|e| e.log.len()).unwrap_or(0)
    }

    fn entry(&self, id: u64) -> Result<&ModelEntry, HubError> {
        self.entries.get(&id).ok_or(HubError::UnknownModel(format!("#{id}")))
    }

    fn touch(&mut self, id: u64) {
        self.lru.retain(|&x| x != id);
        self.lru.push(id);
    }

    /// Evict coldest hot replicas (never `keep`) until `need` more
    /// bytes fit under the budget. Typed failure when nothing is
    /// evictable — never a silent drop, never an over-budget admit.
    fn make_room(&mut self, need: usize, keep: u64) -> Result<(), HubError> {
        if self.cfg.memory_budget == 0 {
            return Ok(());
        }
        while self.resident_bytes() + need > self.cfg.memory_budget {
            let victim = self.lru.iter().copied().find(|&id| {
                id != keep && matches!(self.entries[&id].state, Residency::Hot(_))
            });
            match victim {
                Some(id) => self.evict_resident(id)?,
                None => {
                    return Err(HubError::BudgetExhausted {
                        need,
                        resident: self.resident_bytes(),
                        budget: self.cfg.memory_budget,
                    })
                }
            }
        }
        Ok(())
    }

    /// Drop a hot machine (checkpoint + retained log stay behind). A
    /// durable hub writes through first, so eviction never widens the
    /// window a crash could force back through WAL replay.
    fn evict_resident(&mut self, id: u64) -> Result<(), HubError> {
        let entry = self.entries.get_mut(&id).expect("evict_resident: known id");
        if matches!(entry.state, Residency::Hot(_)) {
            write_through(self.store.as_mut(), id, entry)?;
            entry.state = Residency::Cold;
            entry.evictions += 1;
        }
        Ok(())
    }

    /// Force-evict a model now (the soak's mid-trace drill, or an
    /// operator drop). No-op on a cold model; typed error mid-evict.
    pub fn evict(&mut self, h: ModelHandle) -> Result<(), HubError> {
        match &self.entry(h.id)?.state {
            Residency::Evicting(_) => Err(HubError::Evicting { model: h.id }),
            Residency::Cold => Ok(()),
            Residency::Hot(_) => self.evict_resident(h.id),
        }
    }

    /// Open the eviction barrier: the machine stays resident but every
    /// request against the model is refused with
    /// [`HubError::Evicting`] until [`ModelHub::finish_evict`]. This is
    /// the deterministic stand-in for an eviction racing in-flight
    /// traffic.
    pub fn begin_evict(&mut self, h: ModelHandle) -> Result<(), HubError> {
        self.entry(h.id)?;
        let entry = self.entries.get_mut(&h.id).expect("begin_evict: known id");
        match std::mem::replace(&mut entry.state, Residency::Cold) {
            Residency::Hot(m) => {
                entry.state = Residency::Evicting(m);
                Ok(())
            }
            Residency::Evicting(m) => {
                entry.state = Residency::Evicting(m);
                Err(HubError::Evicting { model: h.id })
            }
            Residency::Cold => Ok(()),
        }
    }

    /// Close the eviction barrier: drop the machine, count the
    /// eviction. A durable hub writes through first, like
    /// [`ModelHub::evict`].
    pub fn finish_evict(&mut self, h: ModelHandle) -> Result<(), HubError> {
        self.entry(h.id)?;
        let entry = self.entries.get_mut(&h.id).expect("finish_evict: known id");
        if let Residency::Evicting(_) = entry.state {
            write_through(self.store.as_mut(), h.id, entry)?;
            entry.state = Residency::Cold;
            entry.evictions += 1;
        }
        Ok(())
    }

    /// Make a model's machine live, rehydrating bit-identically from
    /// checkpoint + retained-log replay if it was evicted. Touches LRU.
    fn ensure_hot(&mut self, id: u64) -> Result<(), HubError> {
        match &self.entry(id)?.state {
            Residency::Hot(_) => {
                self.touch(id);
                Ok(())
            }
            Residency::Evicting(_) => Err(HubError::Evicting { model: id }),
            Residency::Cold => {
                let cost = self.entries[&id].cost;
                self.make_room(cost, id)?;
                let entry = self.entries.get_mut(&id).expect("ensure_hot: known id");
                let snap = restore(&entry.checkpoint).map_err(|e| HubError::Corrupt {
                    model: id,
                    detail: format!("{e:#}"),
                })?;
                debug_assert_eq!(snap.seq, entry.checkpoint_seq);
                let mut machine = snap.machine;
                for u in &entry.log {
                    machine.apply_update_with(u, &entry.params, entry.base_seed, &mut entry.scratch);
                }
                entry.state = Residency::Hot(Box::new(machine));
                entry.rehydrations += 1;
                self.touch(id);
                Ok(())
            }
        }
    }

    /// Apply one sequenced update to a model; returns its new seq.
    /// Rehydrates transparently; refreshes the checkpoint every
    /// `checkpoint_every` updates so the retained log stays bounded.
    ///
    /// Durable hubs log the update write-ahead: a storage refusal means
    /// the update did not happen, in memory or on disk.
    pub fn update(&mut self, h: ModelHandle, kind: UpdateKind) -> Result<u64, HubError> {
        self.ensure_hot(h.id)?;
        let entry = self.entries.get_mut(&h.id).expect("update: ensured hot");
        if let Some(store) = self.store.as_mut() {
            let op = to_wal_op(&entry.shape, &kind);
            store.log_update(h.id, entry.seq + 1, &op)?;
        }
        entry.seq += 1;
        let u = ShardUpdate { seq: entry.seq, kind };
        let Residency::Hot(machine) = &mut entry.state else {
            unreachable!("update: ensure_hot left the model cold")
        };
        machine.apply_update_with(&u, &entry.params, entry.base_seed, &mut entry.scratch);
        entry.log.push(u);
        if self.cfg.checkpoint_every > 0
            && entry.seq - entry.checkpoint_seq >= self.cfg.checkpoint_every
        {
            let Residency::Hot(machine) = &entry.state else {
                unreachable!("update: ensure_hot left the model cold")
            };
            entry.checkpoint = snapshot_bytes(machine, &entry.params, entry.seq);
            entry.checkpoint_seq = entry.seq;
            entry.log.clear();
            entry.cost = entry.checkpoint.len();
            // The update itself is already durable in the WAL; a failed
            // publish only poisons *future* durable writes (fail-stop),
            // it cannot lose this one.
            if let Some(store) = self.store.as_mut() {
                store.publish_checkpoint(h.id, entry.seq, &entry.checkpoint)?;
            }
        }
        Ok(entry.seq)
    }

    /// Score a batch of inputs against a model, in order. Batches of
    /// more than one sample go through the shared bitplane cache
    /// (transpose once per distinct batch, across all tenants);
    /// single samples take the scalar path. Both are bit-identical to
    /// the scalar oracle — the engine-lane equivalence the corpus
    /// harness pins.
    pub fn infer(&mut self, h: ModelHandle, inputs: &[Input]) -> Result<Vec<usize>, HubError> {
        self.ensure_hot(h.id)?;
        let entry = self.entries.get_mut(&h.id).expect("infer: ensured hot");
        let Residency::Hot(machine) = &mut entry.state else {
            unreachable!("infer: ensure_hot left the model cold")
        };
        if inputs.len() > 1 {
            let planes = self.planes.get_or_build(&entry.shape, inputs);
            Ok(machine.predict_planes(&planes, &entry.params))
        } else {
            Ok(inputs.iter().map(|x| machine.predict(x, &entry.params)).collect())
        }
    }

    /// Read access to a model's machine (rehydrating if needed) — the
    /// digest/replica surface the differential soaks assert on.
    pub fn machine(&mut self, h: ModelHandle) -> Result<&MultiTm, HubError> {
        self.ensure_hot(h.id)?;
        let entry = self.entries.get(&h.id).expect("machine: ensured hot");
        let Residency::Hot(machine) = &entry.state else {
            unreachable!("machine: ensure_hot left the model cold")
        };
        Ok(machine)
    }

    /// State digest of a model's current machine (rehydrating if
    /// needed).
    pub fn digest(&mut self, h: ModelHandle) -> Result<u64, HubError> {
        Ok(self.machine(h)?.state_digest())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::tm::rng::Xoshiro256;

    fn hub_model(seed: u64) -> (MultiTm, TmParams) {
        let s = TmShape::iris();
        let mut rng = Xoshiro256::new(seed);
        (testkit::gen::machine(&mut rng, &s), TmParams::paper_online(&s))
    }

    fn learn(seed: u64, i: u64) -> UpdateKind {
        let s = TmShape::iris();
        let mut rng = Xoshiro256::new(seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        UpdateKind::Learn {
            input: Input::pack(&s, &testkit::gen::bool_vec(&mut rng, s.features, 0.5)),
            label: rng.next_below(s.classes),
        }
    }

    /// The heart of the tentpole: evict mid-log, keep updating, and the
    /// rehydrated machine is bit-identical to a never-evicted mirror
    /// applying the same `(base_seed, seq)` log.
    #[test]
    fn evict_rehydrate_is_bit_identical() {
        let (machine, params) = hub_model(0xA11);
        let mut mirror = machine.clone();
        let mut hub =
            ModelHub::new(HubConfig { checkpoint_every: 8, ..Default::default() });
        let h = hub.create("tenant0", machine, params.clone(), 0xBA5E).unwrap();
        for i in 0..30u64 {
            let kind = learn(7, i);
            let seq = hub.update(h, kind.clone()).unwrap();
            assert_eq!(seq, i + 1, "hub seq tracks the log clock");
            mirror.apply_update(&ShardUpdate { seq, kind }, &params, 0xBA5E);
            if i % 11 == 3 {
                hub.evict(h).unwrap();
                assert!(!hub.is_hot(h));
            }
        }
        assert_eq!(hub.digest(h).unwrap(), mirror.state_digest());
        let (ev, reh) = hub.lifecycle(h);
        assert!(ev >= 2 && reh >= 2, "evictions {ev}, rehydrations {reh}");
        // Checkpoint refresh bounds the retained log.
        assert!(hub.retained_log_len(h) <= 8);
    }

    /// Batched inference through the shared plane cache matches the
    /// scalar path exactly, and a second tenant reuses the transpose.
    #[test]
    fn batched_inference_matches_scalar_and_shares_planes() {
        let (m0, params) = hub_model(0xB0);
        let (m1, _) = hub_model(0xB1);
        let s = TmShape::iris();
        let mut hub = ModelHub::new(HubConfig::default());
        let h0 = hub.create("a", m0.clone(), params.clone(), 1).unwrap();
        let h1 = hub.create("b", m1, params.clone(), 2).unwrap();
        let mut rng = Xoshiro256::new(0xBEEF);
        let batch: Vec<Input> = (0..10)
            .map(|_| Input::pack(&s, &testkit::gen::bool_vec(&mut rng, s.features, 0.5)))
            .collect();
        let got = hub.infer(h0, &batch).unwrap();
        let mut scalar = m0;
        let want: Vec<usize> = batch.iter().map(|x| scalar.predict(x, &params)).collect();
        assert_eq!(got, want);
        hub.infer(h1, &batch).unwrap();
        let (hits, misses) = hub.plane_cache_stats();
        assert_eq!((hits, misses), (1, 1), "tenant b must reuse tenant a's transpose");
    }

    /// LRU under a 2-model budget: the coldest hot replica is evicted,
    /// and touching a cold model brings it back while staying in
    /// budget.
    #[test]
    fn lru_eviction_respects_budget() {
        let (m, params) = hub_model(0xC0);
        let cost = snapshot_bytes(&m, &params, 0).len();
        let mut hub = ModelHub::new(HubConfig {
            memory_budget: 2 * cost,
            ..Default::default()
        });
        let ha = hub.create("a", m.clone(), params.clone(), 1).unwrap();
        let hb = hub.create("b", m.clone(), params.clone(), 2).unwrap();
        let hc = hub.create("c", m.clone(), params.clone(), 3).unwrap();
        // Creating c had to evict the coldest (a).
        assert!(!hub.is_hot(ha));
        assert!(hub.is_hot(hb) && hub.is_hot(hc));
        assert!(hub.resident_bytes() <= 2 * cost);
        // Touch b, then wake a: the coldest hot model is now c.
        hub.infer(hb, &[]).unwrap();
        hub.infer(ha, &[]).unwrap();
        assert!(hub.is_hot(ha) && hub.is_hot(hb));
        assert!(!hub.is_hot(hc));
        assert!(hub.resident_bytes() <= 2 * cost);
    }

    /// The mid-eviction barrier refuses traffic typed — the
    /// deterministic form of "eviction racing an in-flight Learn" —
    /// and the model is consistent once the barrier closes.
    #[test]
    fn eviction_barrier_rejects_racing_learn_typed() {
        let (machine, params) = hub_model(0xD0);
        let mut mirror = machine.clone();
        let mut hub = ModelHub::new(HubConfig::default());
        let h = hub.create("t", machine, params.clone(), 9).unwrap();
        let seq = hub.update(h, learn(1, 0)).unwrap();
        mirror.apply_update(&ShardUpdate { seq, kind: learn(1, 0) }, &params, 9);

        hub.begin_evict(h).unwrap();
        assert_eq!(
            hub.update(h, learn(1, 1)).unwrap_err(),
            HubError::Evicting { model: h.id() },
            "a Learn racing the eviction barrier must be refused typed"
        );
        assert_eq!(hub.infer(h, &[]).unwrap_err(), HubError::Evicting { model: h.id() });
        // The resident-but-evicting replica still counts against memory.
        assert!(hub.resident_bytes() > 0);
        hub.finish_evict(h).unwrap();
        assert!(!hub.is_hot(h));
        // Post-barrier: the refused Learn never happened; the next one
        // resumes the log exactly where it left off.
        let seq = hub.update(h, learn(1, 2)).unwrap();
        assert_eq!(seq, 2);
        mirror.apply_update(&ShardUpdate { seq, kind: learn(1, 2) }, &params, 9);
        assert_eq!(hub.digest(h).unwrap(), mirror.state_digest());
    }

    /// Budget exhaustion with nothing evictable is a typed rejection,
    /// both at creation and at rehydration.
    #[test]
    fn budget_exhaustion_is_typed_rejection() {
        let (m, params) = hub_model(0xE0);
        let cost = snapshot_bytes(&m, &params, 0).len();
        // Budget below one model: creation refuses typed.
        let mut tiny = ModelHub::new(HubConfig { memory_budget: cost - 1, ..Default::default() });
        match tiny.create("a", m.clone(), params.clone(), 1) {
            Err(HubError::BudgetExhausted { need, budget, .. }) => {
                assert_eq!(need, cost);
                assert_eq!(budget, cost - 1);
            }
            other => panic!("want BudgetExhausted, got {other:?}"),
        }
        // Budget of exactly one model, which is pinned mid-eviction: a
        // second model cannot be admitted and the refusal is typed.
        let mut hub = ModelHub::new(HubConfig { memory_budget: cost, ..Default::default() });
        let ha = hub.create("a", m.clone(), params.clone(), 1).unwrap();
        hub.begin_evict(ha).unwrap();
        assert!(matches!(
            hub.create("b", m.clone(), params.clone(), 2),
            Err(HubError::BudgetExhausted { .. })
        ));
        hub.finish_evict(ha).unwrap();
        // Barrier closed → the budget frees and b fits.
        let hb = hub.create("b", m, params, 2).unwrap();
        assert!(hub.is_hot(hb));
    }

    /// Name hygiene: bad and duplicate names refuse typed; lookups on
    /// unknown names return nothing.
    #[test]
    fn names_are_validated_and_unique() {
        let (m, params) = hub_model(0xF0);
        let mut hub = ModelHub::new(HubConfig::default());
        assert!(matches!(
            hub.create("", m.clone(), params.clone(), 1),
            Err(HubError::BadName(_))
        ));
        assert!(matches!(
            hub.create("has space", m.clone(), params.clone(), 1),
            Err(HubError::BadName(_))
        ));
        hub.create("tenant-1", m.clone(), params.clone(), 1).unwrap();
        assert!(matches!(
            hub.create("tenant-1", m, params, 2),
            Err(HubError::DuplicateName(_))
        ));
        assert!(hub.resolve("tenant-1").is_some());
        assert!(hub.resolve("tenant-2").is_none());
        assert_eq!(hub.default_handle(), hub.resolve("tenant-1"));
    }

    use crate::store::{testdir, RealDisk, StoreConfig};

    fn open_store(dir: &std::path::Path) -> (Store, Vec<crate::store::RecoveredModel>) {
        Store::open(Box::new(RealDisk), dir, StoreConfig::default()).unwrap()
    }

    /// The durability tentpole at hub scope: two tenants, interleaved
    /// updates (Learn and ClauseFault) and a forced mid-log eviction,
    /// then the hub is dropped and rebuilt from disk twice over — every
    /// digest bit-identical to never-persisted in-memory mirrors fed
    /// the same keyed log, including updates applied *after* the
    /// restarts.
    #[test]
    fn durable_hub_restart_is_bit_identical() {
        let dir = testdir("hub_restart");
        let cfg = HubConfig { checkpoint_every: 8, ..Default::default() };
        let (m0, params) = hub_model(0x10);
        let (m1, _) = hub_model(0x11);
        let mut mirrors = [m0.clone(), m1.clone()];
        let seeds = [0xA0u64, 0xB1];
        let mut seqs = [0u64; 2];
        #[allow(clippy::too_many_arguments)]
        fn step(
            hub: &mut ModelHub,
            handles: &[ModelHandle; 2],
            mirrors: &mut [MultiTm; 2],
            seqs: &mut [u64; 2],
            seeds: &[u64; 2],
            params: &TmParams,
            t: usize,
            i: u64,
        ) {
            let kind = if i % 7 == 5 {
                UpdateKind::ClauseFault {
                    class: (i % 3) as usize,
                    clause: (i % 16) as usize,
                    force: [None, Some(false), Some(true)][(i % 3) as usize],
                }
            } else {
                learn(seeds[t], i)
            };
            let seq = hub.update(handles[t], kind.clone()).unwrap();
            seqs[t] += 1;
            assert_eq!(seq, seqs[t]);
            mirrors[t].apply_update(&ShardUpdate { seq, kind }, params, seeds[t]);
        }

        let (store, recovered) = open_store(&dir);
        assert!(recovered.is_empty(), "fresh directory must rebuild an empty hub");
        let mut hub = ModelHub::open_durable(cfg.clone(), store, recovered).unwrap();
        let handles = [
            hub.create("alpha", m0, params.clone(), seeds[0]).unwrap(),
            hub.create("beta", m1, params.clone(), seeds[1]).unwrap(),
        ];
        for i in 0..21u64 {
            step(&mut hub, &handles, &mut mirrors, &mut seqs, &seeds, &params, (i % 2) as usize, i);
            if i == 13 {
                hub.evict(handles[0]).unwrap();
            }
        }
        drop(hub);

        // First restart: identity, seqs and state all rebuilt from
        // manifest + checkpoints + WAL-suffix replay.
        let (store, recovered) = open_store(&dir);
        assert_eq!(recovered.len(), 2);
        let mut hub = ModelHub::open_durable(cfg.clone(), store, recovered).unwrap();
        assert_eq!(hub.resolve("alpha"), Some(handles[0]));
        assert_eq!(hub.resolve("beta"), Some(handles[1]));
        assert_eq!(hub.default_handle(), Some(handles[0]));
        for t in 0..2 {
            assert_eq!(hub.model_seq(handles[t]), Some(seqs[t]));
            assert_eq!(hub.digest(handles[t]).unwrap(), mirrors[t].state_digest(), "tenant {t}");
        }
        // Keep updating the rebuilt hub: the keyed log clock continues
        // exactly where it stopped.
        for i in 21..34u64 {
            step(&mut hub, &handles, &mut mirrors, &mut seqs, &seeds, &params, (i % 2) as usize, i);
        }
        drop(hub);

        // Second restart, purely to show rebuild composes.
        let (store, recovered) = open_store(&dir);
        let mut hub = ModelHub::open_durable(cfg, store, recovered).unwrap();
        for t in 0..2 {
            assert_eq!(hub.digest(handles[t]).unwrap(), mirrors[t].state_digest(), "tenant {t}");
        }
        // A name collision with a recovered model still refuses typed.
        let (m2, _) = hub_model(0x12);
        assert!(matches!(
            hub.create("alpha", m2, params, 3),
            Err(HubError::DuplicateName(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Eviction writes through: the store's newest durable checkpoint
    /// jumps to the eviction seq, the retained log empties, and the
    /// next rehydration replays nothing yet stays bit-identical.
    #[test]
    fn durable_eviction_writes_through_to_disk() {
        let dir = testdir("hub_evict_wt");
        let (machine, params) = hub_model(0x20);
        let mut mirror = machine.clone();
        let (store, recovered) = open_store(&dir);
        let mut hub = ModelHub::open_durable(
            HubConfig { checkpoint_every: 64, ..Default::default() },
            store,
            recovered,
        )
        .unwrap();
        let h = hub.create("tenant", machine, params.clone(), 0xE1).unwrap();
        for i in 0..5u64 {
            let kind = learn(9, i);
            let seq = hub.update(h, kind.clone()).unwrap();
            mirror.apply_update(&ShardUpdate { seq, kind }, &params, 0xE1);
        }
        assert_eq!(hub.retained_log_len(h), 5);
        hub.evict(h).unwrap();
        assert_eq!(hub.retained_log_len(h), 0, "write-through must fold the log");
        let manifest = hub.store().unwrap().manifest();
        assert_eq!(manifest[&h.id()].ckpt_seq, 5, "durable checkpoint at eviction seq");
        assert_eq!(hub.digest(h).unwrap(), mirror.state_digest());
        // And a cold restart lands on the written-through checkpoint
        // with an empty replay suffix.
        drop(hub);
        let (_store, recovered) = open_store(&dir);
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].ckpt_seq, 5);
        assert!(recovered[0].ops.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
