//! Multi-tenant model hub: handle-based ownership of many served
//! machines behind one routing surface.
//!
//! The serving stack below this module (`crate::serve`, `crate::net`)
//! was built around one implicit global `MultiTm`. The hub closes
//! ROADMAP item 1 by making model ownership explicit: a [`ModelHub`]
//! owns any number of machines behind opaque [`ModelHandle`]s, keeps a
//! per-model sequenced `ShardUpdate` log keyed `(model_id, base_seed,
//! seq)`, shares transposed dataset bitplanes across tenants
//! ([`PlaneCache`]), and evicts cold replicas to in-memory TMFS
//! checkpoints under a configurable memory budget. Eviction is
//! *transparent*: the next request against a cold model restores the
//! checkpoint and replays the retained log suffix, landing on states
//! bit-identical to a never-evicted replica — the same
//! checkpoint-plus-keyed-replay argument the shard supervisor's crash
//! recovery already proves (`crate::serve::supervisor`).
//!
//! The split mirrors bosminer's hub/scheduler/stats layering: the hub
//! owns model lifetime and residency, the front end
//! (`crate::net::frontend`) schedules per-model micro-batches against
//! it through the [`HubNetBackend`] trait, and per-model telemetry
//! flows back over the versioned `stats` frame.

pub mod cache;
pub mod model;

pub use cache::PlaneCache;
pub use model::{HubConfig, HubError, ModelHandle, ModelHub};

use crate::serve::{NetBackend, NetFinal, PendingRequest, ServeBackend};
use crate::tm::clause::Input;
use crate::tm::params::TmShape;
use crate::tm::update::UpdateKind;

/// Typed routing failure surfaced to the front end, which maps it onto
/// the wire's `err kind=` vocabulary (`unknown-model`, `evicting`,
/// `overload`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// No model is bound under the requested name (or the backend
    /// serves a single anonymous model and a name was given).
    UnknownModel,
    /// The model is mid-eviction; the request raced the residency
    /// barrier and is rejected typed rather than blocked or dropped.
    Evicting,
    /// Admitting the model would exceed the hub's memory budget and no
    /// resident replica is evictable.
    Budget,
    /// The hub could not reconstruct the model (a failed checkpoint
    /// restore) — never expected in-memory, but typed rather than a
    /// panic in the serving loop.
    Internal,
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel => write!(f, "unknown model"),
            RouteError::Evicting => write!(f, "model is evicting"),
            RouteError::Budget => write!(f, "model memory budget exhausted"),
            RouteError::Internal => write!(f, "model could not be rehydrated"),
        }
    }
}

impl std::error::Error for RouteError {}

/// The model-scoped serving backend the front end drives: every update
/// and inference batch names the model it belongs to, and the backend
/// reports per-model shape, telemetry and lifecycle counters. This is
/// the handle-scoped replacement for the implicit-global-machine
/// [`NetBackend`] surface; any legacy single-model backend still
/// satisfies it through the blanket impl below (as the anonymous
/// default model, id 0), which is exactly how pre-hub wire sessions
/// keep their observable behaviour.
pub trait HubNetBackend {
    /// Resolve a model reference to a routable id. `None` means "the
    /// default model" — what every legacy (model-less) frame binds to.
    fn bind(&self, model: Option<&str>) -> Result<u64, RouteError>;

    /// Human-readable label for a bound model (telemetry rows).
    fn model_label(&self, model: u64) -> String;

    /// Shape served by a bound model, when the backend knows it.
    /// `None` defers to the front end's configured shape.
    fn model_shape(&self, model: u64) -> Option<TmShape>;

    /// Apply one sequenced update to a model. The front end assigns
    /// wire-visible `seq` numbers per model in lockstep with this call.
    fn model_update(&mut self, model: u64, kind: UpdateKind) -> Result<(), RouteError>;

    /// Score one micro-batch against a model. On error the whole batch
    /// is unserved and the front end answers each request typed.
    fn model_infer(&mut self, model: u64, batch: Vec<PendingRequest>) -> Result<(), RouteError>;

    /// Responses produced since the last poll, `(request id, class)`.
    fn poll_responses(&mut self) -> Vec<(u64, usize)>;

    /// Request ids shed server-side since the last poll.
    fn poll_shed(&mut self) -> Vec<u64>;

    /// Per-shard queue depth snapshot for one model (empty when the
    /// backend has no internal queues).
    fn queue_depths(&self, model: u64) -> Vec<u64>;

    /// `(evictions, rehydrations)` lifecycle counters for one model.
    fn lifecycle(&self, model: u64) -> (u64, u64);

    /// Ids of every model this backend serves, ascending.
    fn models(&self) -> Vec<u64>;

    /// Flush any deferred durable writes (WAL appends under a lazy
    /// sync policy) so everything acknowledged so far survives power
    /// loss. The front end calls this at drain, before
    /// [`HubNetBackend::finalize`]. Default: no-op, for in-memory
    /// backends with nothing to flush.
    fn sync_durable(&mut self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Finish serving: join/collect replicas for the differential
    /// report. Replica order follows [`HubNetBackend::models`].
    fn finalize(self) -> anyhow::Result<NetFinal>;
}

/// Adapter serving one legacy single-model [`NetBackend`] as a
/// degenerate hub hosting one anonymous model under id 0. Model-less
/// frames route to it; named lookups fail typed — which is what keeps
/// the pre-hub wire behaviour byte-identical through the front-end
/// redesign. (A blanket `impl<B: NetBackend> HubNetBackend for B`
/// would be cleaner but coherence forbids it next to the concrete
/// [`ModelHub`] impl below, so the wrapper is explicit.)
pub struct SingleModel<B: NetBackend>(pub B);

impl<B: NetBackend> HubNetBackend for SingleModel<B> {
    fn bind(&self, model: Option<&str>) -> Result<u64, RouteError> {
        match model {
            None => Ok(0),
            Some(_) => Err(RouteError::UnknownModel),
        }
    }

    fn model_label(&self, _model: u64) -> String {
        "default".to_string()
    }

    fn model_shape(&self, _model: u64) -> Option<TmShape> {
        None
    }

    fn model_update(&mut self, _model: u64, kind: UpdateKind) -> Result<(), RouteError> {
        ServeBackend::update(&mut self.0, kind);
        Ok(())
    }

    fn model_infer(&mut self, _model: u64, batch: Vec<PendingRequest>) -> Result<(), RouteError> {
        ServeBackend::infer_batch(&mut self.0, batch);
        Ok(())
    }

    fn poll_responses(&mut self) -> Vec<(u64, usize)> {
        NetBackend::poll_responses(&mut self.0)
    }

    fn poll_shed(&mut self) -> Vec<u64> {
        NetBackend::poll_shed(&mut self.0)
    }

    fn queue_depths(&self, _model: u64) -> Vec<u64> {
        NetBackend::queue_depths(&self.0)
    }

    fn lifecycle(&self, _model: u64) -> (u64, u64) {
        (0, 0)
    }

    fn models(&self) -> Vec<u64> {
        vec![0]
    }

    fn finalize(self) -> anyhow::Result<NetFinal> {
        NetBackend::finalize(self.0)
    }
}

impl From<HubError> for RouteError {
    fn from(e: HubError) -> RouteError {
        match e {
            HubError::Evicting { .. } => RouteError::Evicting,
            HubError::BudgetExhausted { .. } => RouteError::Budget,
            HubError::UnknownModel(_) | HubError::BadName(_) | HubError::DuplicateName(_) => {
                RouteError::UnknownModel
            }
            HubError::Corrupt { .. } | HubError::Storage { .. } => RouteError::Internal,
        }
    }
}

/// The hub itself is the real multi-model backend: every wire `model=`
/// dimension lands here. The hub serves synchronously — responses are
/// produced at dispatch and streamed to the front end on the next poll;
/// it never sheds server-side (refusals are typed `RouteError`s) and
/// has no internal queues.
impl HubNetBackend for ModelHub {
    fn bind(&self, model: Option<&str>) -> Result<u64, RouteError> {
        let h = match model {
            None => self.default_handle(),
            Some(name) => self.resolve(name),
        };
        h.map(|h| h.id()).ok_or(RouteError::UnknownModel)
    }

    fn model_label(&self, model: u64) -> String {
        self.name(ModelHandle::from_id(model)).unwrap_or("?").to_string()
    }

    fn model_shape(&self, model: u64) -> Option<TmShape> {
        self.shape_of(ModelHandle::from_id(model)).cloned()
    }

    fn model_update(&mut self, model: u64, kind: UpdateKind) -> Result<(), RouteError> {
        self.update(ModelHandle::from_id(model), kind).map(|_seq| ()).map_err(RouteError::from)
    }

    fn model_infer(&mut self, model: u64, batch: Vec<PendingRequest>) -> Result<(), RouteError> {
        let ids: Vec<u64> = batch.iter().map(|p| p.id).collect();
        let inputs: Vec<Input> = batch.into_iter().map(|p| p.input).collect();
        let classes = self.infer(ModelHandle::from_id(model), &inputs)?;
        debug_assert_eq!(ids.len(), classes.len());
        self.responses.extend(ids.into_iter().zip(classes));
        Ok(())
    }

    fn poll_responses(&mut self) -> Vec<(u64, usize)> {
        let fresh = self.responses[self.polled..].to_vec();
        self.polled = self.responses.len();
        fresh
    }

    fn poll_shed(&mut self) -> Vec<u64> {
        Vec::new()
    }

    fn queue_depths(&self, _model: u64) -> Vec<u64> {
        Vec::new()
    }

    fn lifecycle(&self, model: u64) -> (u64, u64) {
        ModelHub::lifecycle(self, ModelHandle::from_id(model))
    }

    fn models(&self) -> Vec<u64> {
        self.handles().iter().map(|h| h.id()).collect()
    }

    fn sync_durable(&mut self) -> anyhow::Result<()> {
        ModelHub::sync_durable(self).map_err(|e| anyhow::anyhow!("hub drain: {e}"))
    }

    /// Rehydrates each model in turn (one at a time, so a budget sized
    /// for fewer than all models still drains cleanly) and clones its
    /// final state into the replica report, id-ascending. Durable hubs
    /// flush the WAL first, so a drained run's acknowledged state
    /// survives power loss even under a lazy sync policy.
    fn finalize(mut self) -> anyhow::Result<NetFinal> {
        ModelHub::sync_durable(&mut self).map_err(|e| anyhow::anyhow!("hub drain: {e}"))?;
        let mut responses = std::mem::take(&mut self.responses);
        responses.sort_unstable_by_key(|&(id, _)| id);
        let mut replicas = Vec::new();
        for h in self.handles() {
            let machine = self
                .machine(h)
                .map_err(|e| anyhow::anyhow!("hub drain: model {}: {e}", h.id()))?
                .clone();
            replicas.push(machine);
        }
        Ok(NetFinal { responses, shed: Vec::new(), replicas })
    }
}
