//! Shared dataset bitplane cache: one transpose per distinct input
//! batch, shared across every tenant of a [`crate::hub::ModelHub`].
//!
//! PR 2 cached transposed [`BitPlanes`] dataset-side so sweep grid
//! cells share one transpose; the hub generalises that across tenants.
//! Batches are keyed by content (literal count plus every packed input
//! word), so two tenants scoring the same rows — replayed calibration
//! sets, shared evaluation traffic, fleet drills — transpose once and
//! AND twice. The cache is a bounded FIFO: eviction only costs a
//! re-transpose, never correctness.

use crate::tm::bitplane::BitPlanes;
use crate::tm::clause::Input;
use crate::tm::params::TmShape;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// Content-addressed cache of transposed input batches.
#[derive(Debug)]
pub struct PlaneCache {
    map: HashMap<u64, Arc<BitPlanes>>,
    order: VecDeque<u64>,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl PlaneCache {
    /// A cache holding at most `capacity` distinct batches (0 is
    /// clamped to 1: a zero-capacity cache would still be correct but
    /// only ever thrash).
    pub fn new(capacity: usize) -> Self {
        PlaneCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
        }
    }

    /// The transpose of `inputs`, built on first sight and shared
    /// thereafter. Keyed by literal count + input content, so any two
    /// shapes with the same literal width share entries soundly (the
    /// transpose is a pure function of exactly those).
    pub fn get_or_build(&mut self, shape: &TmShape, inputs: &[Input]) -> Arc<BitPlanes> {
        let key = batch_key(shape, inputs);
        if let Some(planes) = self.map.get(&key) {
            self.hits += 1;
            return Arc::clone(planes);
        }
        self.misses += 1;
        let planes = Arc::new(BitPlanes::from_inputs(shape, inputs));
        if self.map.len() >= self.capacity {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
        self.map.insert(key, Arc::clone(&planes));
        self.order.push_back(key);
        planes
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// FNV-1a over the batch content: literal width, sample count, then
/// every packed word of every input in order.
fn batch_key(shape: &TmShape, inputs: &[Input]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    mix(shape.literals() as u64);
    mix(inputs.len() as u64);
    for input in inputs {
        for &w in input.words() {
            mix(w);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::tm::rng::Xoshiro256;

    fn batch(seed: u64, n: usize) -> Vec<Input> {
        let s = TmShape::iris();
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| Input::pack(&s, &testkit::gen::bool_vec(&mut rng, s.features, 0.5)))
            .collect()
    }

    /// The same batch content hits regardless of which tenant asks;
    /// different content misses.
    #[test]
    fn identical_batches_share_one_transpose() {
        let s = TmShape::iris();
        let mut cache = PlaneCache::new(8);
        let a = batch(1, 12);
        let p1 = cache.get_or_build(&s, &a);
        let p2 = cache.get_or_build(&s, &a.clone());
        assert!(Arc::ptr_eq(&p1, &p2), "second tenant must reuse the transpose");
        assert_eq!(cache.stats(), (1, 1));
        let b = batch(2, 12);
        let p3 = cache.get_or_build(&s, &b);
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!(cache.stats(), (1, 2));
    }

    /// Cached planes are bit-identical to a fresh transpose.
    #[test]
    fn cached_planes_match_fresh_transpose() {
        let s = TmShape::iris();
        let mut cache = PlaneCache::new(4);
        let a = batch(3, 20);
        let cached = cache.get_or_build(&s, &a);
        let fresh = BitPlanes::from_inputs(&s, &a);
        assert_eq!(cached.fingerprint(), fresh.fingerprint());
        assert_eq!(cached.len(), fresh.len());
    }

    /// Capacity bounds the cache; evicted entries rebuild correctly.
    #[test]
    fn fifo_eviction_is_bounded_and_sound() {
        let s = TmShape::iris();
        let mut cache = PlaneCache::new(2);
        let batches: Vec<_> = (0..4).map(|i| batch(10 + i, 6)).collect();
        for b in &batches {
            cache.get_or_build(&s, b);
        }
        assert_eq!(cache.len(), 2);
        // The oldest entry was evicted: asking again is a miss, but the
        // rebuilt transpose is identical.
        let (_, misses_before) = cache.stats();
        let rebuilt = cache.get_or_build(&s, &batches[0]);
        assert_eq!(cache.stats().1, misses_before + 1);
        assert_eq!(rebuilt.fingerprint(), BitPlanes::from_inputs(&s, &batches[0]).fingerprint());
    }
}
