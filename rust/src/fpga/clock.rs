//! Clock domain and clock-gating model (paper §6).
//!
//! The RTL clock-gates the TM core when no inference/learning is running,
//! and gates over-provisioned clauses/TAs individually. We track, per
//! module, how many cycles its clock was *enabled* vs *gated*; the power
//! model turns enabled-cycle counts plus switching events into energy.

use std::collections::BTreeMap;

/// Module identifiers for activity accounting. One per paper subsystem.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Module {
    /// The TM core: clause bank + TA registers (active slice).
    TmCore,
    /// Over-provisioned (gated-off) clauses/TAs.
    TmOverProvision,
    /// High- and low-level management FSMs.
    Management,
    /// Accuracy-analysis block.
    AccuracyAnalysis,
    /// Offline memory manager + block ROMs.
    OfflineMemory,
    /// Online input path (parser, cyclic buffer, manager).
    OnlineInput,
    /// AXI register file + handshake logic.
    AxiInterface,
    /// Fault controller.
    FaultController,
}

pub const ALL_MODULES: [Module; 8] = [
    Module::TmCore,
    Module::TmOverProvision,
    Module::Management,
    Module::AccuracyAnalysis,
    Module::OfflineMemory,
    Module::OnlineInput,
    Module::AxiInterface,
    Module::FaultController,
];

/// Per-module cycle/event accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModuleActivity {
    /// Cycles the module's clock was enabled.
    pub active_cycles: u64,
    /// Cycles the module existed but was clock-gated.
    pub gated_cycles: u64,
    /// Switching events (e.g. TA updates, clause evaluations) — feeds the
    /// dynamic-power term.
    pub toggle_events: u64,
}

/// The system clock: a cycle counter plus per-module gating state.
#[derive(Debug, Clone)]
pub struct Clock {
    cycle: u64,
    enabled: BTreeMap<Module, bool>,
    activity: BTreeMap<Module, ModuleActivity>,
}

impl Default for Clock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock {
    pub fn new() -> Self {
        let mut enabled = BTreeMap::new();
        let mut activity = BTreeMap::new();
        for m in ALL_MODULES {
            // Reset state: everything gated until the FSM enables it —
            // the paper's "when inference or learning is not occurring,
            // the TM is clock-gated".
            enabled.insert(m, false);
            activity.insert(m, ModuleActivity::default());
        }
        Clock { cycle: 0, enabled, activity }
    }

    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// Gate or un-gate a module's clock.
    pub fn set_enabled(&mut self, m: Module, on: bool) {
        *self.enabled.get_mut(&m).unwrap() = on;
    }

    pub fn is_enabled(&self, m: Module) -> bool {
        self.enabled[&m]
    }

    /// Advance the clock by `n` cycles, crediting each module according to
    /// its gating state.
    pub fn advance(&mut self, n: u64) {
        self.cycle += n;
        for m in ALL_MODULES {
            let a = self.activity.get_mut(&m).unwrap();
            if self.enabled[&m] {
                a.active_cycles += n;
            } else {
                a.gated_cycles += n;
            }
        }
    }

    /// Record `n` switching events on a module.
    pub fn toggle(&mut self, m: Module, n: u64) {
        self.activity.get_mut(&m).unwrap().toggle_events += n;
    }

    pub fn activity(&self, m: Module) -> ModuleActivity {
        self.activity[&m]
    }

    /// Run a closure with a module temporarily enabled, then re-gate it.
    pub fn with_enabled<R>(&mut self, m: Module, f: impl FnOnce(&mut Clock) -> R) -> R {
        let prev = self.enabled[&m];
        self.set_enabled(m, true);
        let r = f(self);
        self.set_enabled(m, prev);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_state_fully_gated() {
        let c = Clock::new();
        assert_eq!(c.now(), 0);
        for m in ALL_MODULES {
            assert!(!c.is_enabled(m));
        }
    }

    #[test]
    fn advance_credits_by_gating_state() {
        let mut c = Clock::new();
        c.set_enabled(Module::TmCore, true);
        c.advance(10);
        assert_eq!(c.now(), 10);
        assert_eq!(c.activity(Module::TmCore).active_cycles, 10);
        assert_eq!(c.activity(Module::TmCore).gated_cycles, 0);
        assert_eq!(c.activity(Module::Management).gated_cycles, 10);
        c.set_enabled(Module::TmCore, false);
        c.advance(5);
        assert_eq!(c.activity(Module::TmCore).active_cycles, 10);
        assert_eq!(c.activity(Module::TmCore).gated_cycles, 5);
    }

    #[test]
    fn toggles_accumulate() {
        let mut c = Clock::new();
        c.toggle(Module::TmCore, 3);
        c.toggle(Module::TmCore, 4);
        assert_eq!(c.activity(Module::TmCore).toggle_events, 7);
    }

    #[test]
    fn with_enabled_restores_gating() {
        let mut c = Clock::new();
        let r = c.with_enabled(Module::AccuracyAnalysis, |c| {
            c.advance(4);
            42
        });
        assert_eq!(r, 42);
        assert!(!c.is_enabled(Module::AccuracyAnalysis));
        assert_eq!(c.activity(Module::AccuracyAnalysis).active_cycles, 4);
    }
}
