//! On-board block ROM model (paper §3.6.2).
//!
//! "Each block was stored in a separate block ROM, mapped to via the
//! cross-validation IP. Each block ROM was dual port to allow the Online
//! Training set to be used in online training as well as accuracy
//! analysis." Reads have 1-cycle latency (synchronous BRAM).

use crate::data::dataset::BoolDataset;
use anyhow::{bail, Result};

/// Read latency of a synchronous block RAM, in cycles.
pub const ROM_READ_LATENCY: u64 = 1;

/// ROM port id (block RAMs on the target fabric are dual-port).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Port {
    A,
    B,
}

/// One block ROM holding one cross-validation block.
#[derive(Debug, Clone)]
pub struct BlockRom {
    rows: Vec<(Vec<bool>, usize)>,
    /// Per-port read counters (utilisation statistics).
    reads: [u64; 2],
}

impl BlockRom {
    pub fn from_block(block: &BoolDataset) -> Self {
        let rows = block
            .rows
            .iter()
            .cloned()
            .zip(block.labels.iter().copied())
            .collect();
        BlockRom { rows, reads: [0, 0] }
    }

    pub fn depth(&self) -> usize {
        self.rows.len()
    }

    /// Synchronous read: returns the row plus the cycle cost.
    pub fn read(&mut self, port: Port, addr: usize) -> Result<(&(Vec<bool>, usize), u64)> {
        if addr >= self.rows.len() {
            bail!("ROM address {addr} out of range (depth {})", self.rows.len());
        }
        self.reads[port as usize] += 1;
        Ok((&self.rows[addr], ROM_READ_LATENCY))
    }

    pub fn reads(&self, port: Port) -> u64 {
        self.reads[port as usize]
    }
}

/// The bank of block ROMs plus the cross-validation mapping: a *set*-level
/// address (set, row) resolves through the current block ordering to
/// (block ROM, offset).
#[derive(Debug, Clone)]
pub struct RomBank {
    roms: Vec<BlockRom>,
    block_len: usize,
    /// Current ordering (block ids); set boundaries from the allocation.
    ordering: Vec<usize>,
    /// Blocks per set: (offline, validation, online).
    alloc: (usize, usize, usize),
}

/// Which of the three sets an access targets (§3.6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetId {
    OfflineTrain,
    Validation,
    OnlineTrain,
}

impl RomBank {
    pub fn new(
        blocks: &[BoolDataset],
        ordering: &[usize],
        alloc: (usize, usize, usize),
    ) -> Result<Self> {
        if blocks.is_empty() {
            bail!("no blocks");
        }
        let block_len = blocks[0].len();
        if blocks.iter().any(|b| b.len() != block_len) {
            bail!("blocks must be equal length");
        }
        if ordering.len() != blocks.len() || alloc.0 + alloc.1 + alloc.2 != blocks.len() {
            bail!("ordering/allocation must cover all blocks");
        }
        Ok(RomBank {
            roms: blocks.iter().map(BlockRom::from_block).collect(),
            block_len,
            ordering: ordering.to_vec(),
            alloc,
        })
    }

    /// Re-program the block ordering at runtime (the cross-validation IP's
    /// "starting orderings ... easily manipulated" port).
    pub fn set_ordering(&mut self, ordering: &[usize]) -> Result<()> {
        if ordering.len() != self.roms.len() {
            bail!("ordering length mismatch");
        }
        self.ordering = ordering.to_vec();
        Ok(())
    }

    /// Number of rows in a set.
    pub fn set_len(&self, set: SetId) -> usize {
        let blocks = match set {
            SetId::OfflineTrain => self.alloc.0,
            SetId::Validation => self.alloc.1,
            SetId::OnlineTrain => self.alloc.2,
        };
        blocks * self.block_len
    }

    fn set_base(&self, set: SetId) -> usize {
        match set {
            SetId::OfflineTrain => 0,
            SetId::Validation => self.alloc.0,
            SetId::OnlineTrain => self.alloc.0 + self.alloc.1,
        }
    }

    /// Resolve a set-relative row to (block ROM index, offset).
    pub fn resolve(&self, set: SetId, row: usize) -> Result<(usize, usize)> {
        if row >= self.set_len(set) {
            bail!("row {row} out of range for {set:?} (len {})", self.set_len(set));
        }
        let slot = self.set_base(set) + row / self.block_len;
        Ok((self.ordering[slot], row % self.block_len))
    }

    /// Read one set-relative row; returns ((bits, label), cycles).
    pub fn read(
        &mut self,
        set: SetId,
        row: usize,
        port: Port,
    ) -> Result<((Vec<bool>, usize), u64)> {
        let (rom, offset) = self.resolve(set, row)?;
        let (data, cyc) = self.roms[rom].read(port, offset)?;
        Ok((data.clone(), cyc))
    }

    pub fn rom(&self, i: usize) -> &BlockRom {
        &self.roms[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockPlan;
    use crate::data::iris;

    fn bank() -> RomBank {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 1).unwrap();
        let blocks: Vec<BoolDataset> = (0..5).map(|i| plan.block(i).clone()).collect();
        RomBank::new(&blocks, &[0, 1, 2, 3, 4], (1, 2, 2)).unwrap()
    }

    #[test]
    fn set_lengths_match_paper() {
        let b = bank();
        assert_eq!(b.set_len(SetId::OfflineTrain), 30);
        assert_eq!(b.set_len(SetId::Validation), 60);
        assert_eq!(b.set_len(SetId::OnlineTrain), 60);
    }

    #[test]
    fn resolve_respects_ordering() {
        let mut b = bank();
        assert_eq!(b.resolve(SetId::OfflineTrain, 0).unwrap(), (0, 0));
        assert_eq!(b.resolve(SetId::Validation, 0).unwrap(), (1, 0));
        assert_eq!(b.resolve(SetId::Validation, 30).unwrap(), (2, 0));
        assert_eq!(b.resolve(SetId::OnlineTrain, 59).unwrap(), (4, 29));
        b.set_ordering(&[4, 3, 2, 1, 0]).unwrap();
        assert_eq!(b.resolve(SetId::OfflineTrain, 0).unwrap(), (4, 0));
        assert_eq!(b.resolve(SetId::OnlineTrain, 0).unwrap(), (1, 0));
    }

    #[test]
    fn read_returns_latency_and_counts_ports() {
        let mut b = bank();
        let ((bits, label), cyc) = b.read(SetId::OfflineTrain, 3, Port::A).unwrap();
        assert_eq!(bits.len(), 16);
        assert!(label < 3);
        assert_eq!(cyc, ROM_READ_LATENCY);
        b.read(SetId::OnlineTrain, 0, Port::B).unwrap();
        assert_eq!(b.rom(0).reads(Port::A), 1);
        assert_eq!(b.rom(3).reads(Port::B), 1);
    }

    #[test]
    fn dual_port_independent_counters() {
        let mut b = bank();
        for _ in 0..4 {
            b.read(SetId::OnlineTrain, 0, Port::A).unwrap();
        }
        b.read(SetId::OnlineTrain, 0, Port::B).unwrap();
        assert_eq!(b.rom(3).reads(Port::A), 4);
        assert_eq!(b.rom(3).reads(Port::B), 1);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut b = bank();
        assert!(b.read(SetId::OfflineTrain, 30, Port::A).is_err());
        assert!(b.resolve(SetId::Validation, 60).is_err());
    }

    #[test]
    fn mismatched_construction_rejected() {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 1).unwrap();
        let blocks: Vec<BoolDataset> = (0..5).map(|i| plan.block(i).clone()).collect();
        assert!(RomBank::new(&blocks, &[0, 1, 2], (1, 2, 2)).is_err());
        assert!(RomBank::new(&blocks, &[0, 1, 2, 3, 4], (1, 1, 2)).is_err());
        assert!(RomBank::new(&[], &[], (0, 0, 0)).is_err());
    }
}
