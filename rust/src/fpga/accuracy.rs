//! Accuracy-analysis block (paper §3.3).
//!
//! "The accuracy analysis block records the number of errors and total
//! epochs per accuracy analysis cycle. An additional block records the
//! history of these values during simulation in RAM, whereas these values
//! can be immediately offloaded to the microcontroller when implemented on
//! an FPGA to reduce RAM usage."
//!
//! Analysis streams a set through the (pipelined) datapath in inference
//! mode: cycle cost = pipeline fill + one cycle per stored row (filtered
//! rows still occupy their ROM read slot).
//!
//! Scoring runs the **incremental dirty-clause re-scorer**
//! ([`crate::tm::rescore::RescoreCache`], bit-identical to a cold
//! sample-sliced [`MultiTm::predict_planes`] pass and to the row-major
//! batch path) over a per-(set, filter) transposed-plane cache: every
//! analysis point rescores the same stored sets, so the transpose is
//! paid once per filter configuration and each re-score touches only the
//! clauses whose TA actions flipped since the previous analysis point —
//! the dominant cost of the interleaved online train/analyse loop
//! (paper Fig 3) collapses with the dirty fraction as the TM converges.

use crate::data::filter::ClassFilter;
use crate::fpga::clock::{Clock, Module};
use crate::fpga::fsm_low::DatapointEngine;
use crate::fpga::memmgr::MemoryManager;
use crate::fpga::rom::{Port, RomBank, SetId};
use crate::tm::bitplane::PlaneBatch;
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rescore::{RescoreCache, RescoreStats};
use anyhow::Result;

/// One analysis record (what gets offloaded over AXI).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyRecord {
    pub set: SetId,
    pub errors: usize,
    pub total: usize,
    /// Online iteration index at analysis time (0 = after offline
    /// training only).
    pub iteration: usize,
    pub cycles: u64,
}

impl AccuracyRecord {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            1.0 - self.errors as f64 / self.total as f64
        }
    }
}

/// Where analysis records go (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistoryMode {
    /// Keep full history in on-chip RAM (simulation mode).
    OnChipRam,
    /// Offload each record to the MCU immediately (hardware mode — saves
    /// RAM, costs one handshake per record).
    OffloadToMcu,
}

/// The accuracy-analysis block.
#[derive(Debug, Clone)]
pub struct AccuracyAnalyzer {
    pub mode: HistoryMode,
    /// History RAM (only written in `OnChipRam` mode).
    pub history: Vec<AccuracyRecord>,
    /// Per-(set, filter) transposed bitplanes of the streamed rows. The
    /// stream is deterministic given the (fixed) ROM bank, the set id and
    /// the filter; a row fingerprint (inputs + labels) guards staleness
    /// in case the bank is ever remapped under a live analyzer.
    planes: Vec<(SetId, ClassFilter, u64, PlaneBatch)>,
    /// Incremental re-scorer over the cached plane batches: fired-masks
    /// and vote tallies survive between analysis points; only clauses
    /// dirtied by the interleaved training are re-ANDed.
    rescore: RescoreCache,
}

/// Order-sensitive FNV-style fingerprint of a streamed row set (packed
/// literal words + labels) — O(rows · words), far cheaper than the
/// transpose it guards. Shares the fold definition with
/// [`BitPlanes::fingerprint`](crate::tm::bitplane::BitPlanes) so the two
/// invalidation layers cannot drift.
fn stream_fingerprint(rows: &[(Input, usize)]) -> u64 {
    use crate::tm::bitplane::{fnv_fold, FNV_OFFSET};
    let mut h = FNV_OFFSET;
    for (x, y) in rows {
        h = fnv_fold(h, *y as u64 + 1);
        for &w in x.words() {
            h = fnv_fold(h, w);
        }
    }
    h
}

impl AccuracyAnalyzer {
    pub fn new(mode: HistoryMode) -> Self {
        AccuracyAnalyzer {
            mode,
            history: Vec::new(),
            planes: Vec::new(),
            rescore: RescoreCache::new(),
        }
    }

    /// Cumulative incremental re-scoring counters (dirty fraction etc.) —
    /// surfaced in the system's [`crate::fpga::system::RunReport`].
    pub fn rescore_stats(&self) -> RescoreStats {
        self.rescore.stats()
    }

    /// Transposed planes for one streamed set, cached per (set, filter);
    /// rebuilt if the stream's fingerprint no longer matches the cache
    /// (a rebuilt batch carries a new plane fingerprint, which in turn
    /// invalidates the re-scorer's entry for it). Returns the cache
    /// index so the caller can split field borrows.
    fn cached_planes(
        &mut self,
        set: SetId,
        filter: ClassFilter,
        shape: &TmShape,
        rows: &[(Input, usize)],
    ) -> usize {
        let fp = stream_fingerprint(rows);
        match self.planes.iter().position(|(s, f, _, _)| *s == set && *f == filter) {
            Some(i) => {
                if self.planes[i].2 != fp {
                    self.planes[i].2 = fp;
                    self.planes[i].3 = PlaneBatch::from_labelled(shape, rows);
                }
                i
            }
            None => {
                self.planes
                    .push((set, filter, fp, PlaneBatch::from_labelled(shape, rows)));
                self.planes.len() - 1
            }
        }
    }

    /// Analyse one set: stream it through the inference datapath
    /// (pipelined, port A), count errors. Advances the clock; returns the
    /// record (and stores it when in RAM mode).
    pub fn analyze(
        &mut self,
        tm: &mut MultiTm,
        params: &TmParams,
        mm: &MemoryManager,
        bank: &mut RomBank,
        set: SetId,
        iteration: usize,
        clock: &mut Clock,
    ) -> Result<AccuracyRecord> {
        let start = clock.now();
        let (rows, mem_cycles) = mm.stream(bank, set, Port::A, None)?;
        // Pipelined: ROM reads overlap compute; the stream occupies
        // max(stored rows, fill + passing rows) cycles. Filtered rows
        // consume their read slot but no compute slot.
        let compute = DatapointEngine::pipelined_cycles(rows.len());
        let cycles = mem_cycles.max(compute);
        clock.set_enabled(Module::TmCore, true);
        clock.with_enabled(Module::AccuracyAnalysis, |c| {
            c.with_enabled(Module::OfflineMemory, |c| c.advance(cycles))
        });
        clock.set_enabled(Module::TmCore, false);
        clock.toggle(Module::AccuracyAnalysis, rows.len() as u64);

        // Incremental sample-sliced inference off the cached transpose:
        // only clauses dirtied since the previous analysis of this batch
        // are re-ANDed (bit-identical to per-row `predict`, the row-major
        // batch path and a cold plane pass — see
        // rust/tests/integration_bitplane.rs and integration_rescore.rs).
        let errors = {
            let i = self.cached_planes(set, mm.filter, tm.shape(), &rows);
            let batch = &self.planes[i].3;
            let preds = self.rescore.predict(tm, batch.planes(), params);
            preds.iter().zip(batch.labels().iter()).filter(|(p, y)| p != y).count()
        };
        let rec = AccuracyRecord {
            set,
            errors,
            total: rows.len(),
            iteration,
            cycles: clock.now() - start,
        };
        if self.mode == HistoryMode::OnChipRam {
            self.history.push(rec);
        }
        Ok(rec)
    }

    /// History RAM words in use (each record packs into 4 words).
    pub fn ram_words(&self) -> usize {
        self.history.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockPlan;
    use crate::data::dataset::BoolDataset;
    use crate::data::filter::ClassFilter;
    use crate::data::iris;
    use crate::tm::params::TmShape;

    fn bank() -> RomBank {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 1).unwrap();
        let blocks: Vec<BoolDataset> = (0..5).map(|i| plan.block(i).clone()).collect();
        RomBank::new(&blocks, &[0, 1, 2, 3, 4], (1, 2, 2)).unwrap()
    }

    #[test]
    fn untrained_machine_scores_badly_but_counts_everything() {
        let shape = TmShape::iris();
        let mut tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        let mm = MemoryManager::new(&shape);
        let mut b = bank();
        let mut clock = Clock::new();
        let mut an = AccuracyAnalyzer::new(HistoryMode::OnChipRam);
        let rec = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::Validation, 0, &mut clock)
            .unwrap();
        assert_eq!(rec.total, 60);
        // Untrained machine predicts class 0 for everything -> 40 errors.
        assert_eq!(rec.errors, 40);
        assert!((rec.accuracy() - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(an.history.len(), 1);
        assert_eq!(an.ram_words(), 4);
    }

    #[test]
    fn cycle_cost_is_pipelined() {
        let shape = TmShape::iris();
        let mut tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        let mm = MemoryManager::new(&shape);
        let mut b = bank();
        let mut clock = Clock::new();
        let mut an = AccuracyAnalyzer::new(HistoryMode::OnChipRam);
        let rec = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::OfflineTrain, 0, &mut clock)
            .unwrap();
        // 30 rows: fill(3) + 30 = 33 cycles.
        assert_eq!(rec.cycles, 33);
        assert_eq!(clock.now(), 33);
        assert_eq!(clock.activity(Module::AccuracyAnalysis).active_cycles, 33);
        assert_eq!(clock.activity(Module::TmCore).active_cycles, 33);
    }

    #[test]
    fn filtered_rows_occupy_memory_slots_only() {
        let shape = TmShape::iris();
        let mut tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        let mut mm = MemoryManager::new(&shape);
        mm.filter = ClassFilter::removing(0);
        let mut b = bank();
        let mut clock = Clock::new();
        let mut an = AccuracyAnalyzer::new(HistoryMode::OffloadToMcu);
        let rec = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::OfflineTrain, 2, &mut clock)
            .unwrap();
        assert_eq!(rec.total, 20, "10 rows filtered");
        // mem scan = 30 reads; compute = fill + 20 = 23 -> max = 30.
        assert_eq!(rec.cycles, 30);
        assert_eq!(rec.iteration, 2);
        assert!(an.history.is_empty(), "offload mode keeps no RAM history");
    }

    #[test]
    fn repeated_analysis_is_incremental_and_identical() {
        let shape = TmShape::iris();
        let mut tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        let mm = MemoryManager::new(&shape);
        let mut b = bank();
        let mut clock = Clock::new();
        let mut an = AccuracyAnalyzer::new(HistoryMode::OnChipRam);
        let a = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::Validation, 0, &mut clock)
            .unwrap();
        let cold = an.rescore_stats();
        assert_eq!(cold.cold_builds, 1, "first analysis builds the cache");
        // Nothing trained in between: the second analysis must serve
        // every clause from cache and report identical errors.
        let b2 = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::Validation, 1, &mut clock)
            .unwrap();
        assert_eq!(a.errors, b2.errors);
        let warm = an.rescore_stats();
        assert_eq!(warm.cold_builds, 1);
        assert_eq!(warm.dirty_clauses, 0, "no TA flipped between analyses");
        assert!(warm.clean_clauses > cold.clean_clauses);
    }

    #[test]
    fn trained_machine_improves() {
        use crate::tm::rng::{StepRands, Xoshiro256};
        let shape = TmShape::iris();
        let mut tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        let mm = MemoryManager::new(&shape);
        let mut b = bank();
        let mut clock = Clock::new();
        let mut an = AccuracyAnalyzer::new(HistoryMode::OnChipRam);
        let before = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::OfflineTrain, 0, &mut clock)
            .unwrap();
        let (rows, _) = mm.stream(&mut b, SetId::OfflineTrain, Port::A, None).unwrap();
        let mut rng = Xoshiro256::new(3);
        for _ in 0..10 {
            for (x, y) in &rows {
                let r = StepRands::draw(&mut rng, &shape);
                crate::tm::feedback::train_step(&mut tm, x, *y, &p, &r);
            }
        }
        let after = an
            .analyze(&mut tm, &p, &mm, &mut b, SetId::OfflineTrain, 0, &mut clock)
            .unwrap();
        assert!(after.errors < before.errors, "{} !< {}", after.errors, before.errors);
    }
}
