//! The integrated FPGA system (paper Fig 2): TM core + management FSMs +
//! memory + online input + accuracy analysis + fault controller + AXI/MCU,
//! advancing a single clock with per-module gating, and executing the
//! Fig-3 flow end to end for one block ordering.
//!
//! Cycle accounting models the RTL; the *software* cost of each analysis
//! phase runs the incremental dirty-clause re-scorer over
//! [`AccuracyAnalyzer`]'s per-(set, filter) transposed-plane cache
//! (bit-identical results; one AND per 64 samples, and only for clauses
//! whose TA actions flipped since the previous analysis point — the
//! [`RunReport::rescore`] counters expose how sparse that gets as the
//! run converges).

use crate::data::dataset::BoolDataset;
use crate::data::filter::ClassFilter;
use crate::fpga::accuracy::{AccuracyAnalyzer, AccuracyRecord, HistoryMode};
use crate::fpga::axi::{ctrl, handshake, HandshakeStats, Reg, RegisterFile};
use crate::fpga::clock::{Clock, Module};
use crate::fpga::fault::FaultController;
use crate::fpga::fsm_high::{Event, HighLevelManager, Phase};
use crate::fpga::fsm_low::DatapointEngine;
use crate::fpga::mcu::{Mcu, McuAction};
use crate::fpga::memmgr::MemoryManager;
use crate::fpga::online::OnlineInputPath;
use crate::fpga::power::{PowerModel, PowerReport};
use crate::fpga::rom::{Port, RomBank, SetId};
use crate::tm::bitplane::BitPlanes;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::Xoshiro256;
use crate::tm::train_planes::{train_rows_seq, TrainScratch};
use anyhow::{bail, Result};

/// Full system configuration (the paper's pre-synthesis parameters plus
/// the run-time register values the MCU programs at start-up).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub shape: TmShape,
    /// Blocks per set: (offline, validation, online).
    pub alloc: (usize, usize, usize),
    pub offline_epochs: usize,
    /// Rows of the offline set used for training (paper §5.1 uses 20 of
    /// 30); `None` = all.
    pub offline_train_len: Option<usize>,
    pub online_iterations: usize,
    /// Datapoints per online pass; `None` = one pass over the (filtered)
    /// online set.
    pub online_pass_len: Option<usize>,
    pub s_offline: f32,
    pub s_online: f32,
    pub t: i32,
    pub active_clauses: usize,
    pub active_classes: usize,
    pub analyze_validation: bool,
    pub analyze_online: bool,
    pub history_mode: HistoryMode,
    pub mcu_handshake_latency: u64,
    pub axi_write_cost: u64,
    pub online_buffer_capacity: usize,
    /// The online source produces one row per this many cycles.
    pub online_production_interval: u64,
    /// Class filtered from reset (lifted later via an MCU action).
    pub initial_filter: Option<usize>,
    /// Online learning enabled at reset.
    pub online_learning: bool,
    pub power: PowerModel,
    pub seed: u64,
}

impl SystemConfig {
    /// The paper's §5 experimental configuration.
    pub fn paper() -> Self {
        let shape = TmShape::iris();
        SystemConfig {
            active_clauses: shape.max_clauses,
            active_classes: shape.classes,
            shape,
            alloc: (1, 2, 2),
            offline_epochs: 10,
            offline_train_len: Some(20),
            online_iterations: 16,
            online_pass_len: None,
            s_offline: 1.375,
            s_online: 1.0,
            t: 15,
            analyze_validation: true,
            analyze_online: true,
            history_mode: HistoryMode::OffloadToMcu,
            mcu_handshake_latency: 25,
            axi_write_cost: 4,
            online_buffer_capacity: 64,
            online_production_interval: 4,
            initial_filter: None,
            online_learning: true,
            power: PowerModel::default(),
            seed: 0x7D0,
        }
    }
}

/// Result of one full system run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Accuracy per analysis point (iteration 0..=online_iterations) per
    /// set; `None` where a set wasn't analysed.
    pub offline_curve: Vec<f64>,
    pub validation_curve: Vec<f64>,
    pub online_curve: Vec<f64>,
    pub total_cycles: u64,
    pub handshake: HandshakeStats,
    /// Online datapoints lost to buffer overflow.
    pub dropped_datapoints: usize,
    pub power: PowerReport,
    /// All accuracy records in arrival order (the UART stream).
    pub records: Vec<AccuracyRecord>,
    pub uart_log: Vec<String>,
    /// Switching events on the TM core (power/energy cross-checks).
    pub tm_toggles: u64,
    /// Incremental re-scoring counters of the analysis phases (dirty
    /// fraction across the run's 17 analysis points per set).
    pub rescore: crate::tm::rescore::RescoreStats,
}

/// The integrated system.
pub struct FpgaSystem {
    pub cfg: SystemConfig,
    pub clock: Clock,
    pub regs: RegisterFile,
    pub handshake_stats: HandshakeStats,
    pub tm: MultiTm,
    pub engine: DatapointEngine,
    pub memmgr: MemoryManager,
    pub bank: RomBank,
    pub online: OnlineInputPath,
    pub analyzer: AccuracyAnalyzer,
    pub fault_ctl: FaultController,
    pub mcu: Mcu,
    pub hl: HighLevelManager,
    rng: Xoshiro256,
    scratch: TrainScratch,
    online_learning: bool,
}

impl FpgaSystem {
    /// Build the system for one cross-validation ordering.
    pub fn new(cfg: SystemConfig, blocks: &[BoolDataset], ordering: &[usize]) -> Result<Self> {
        cfg.shape.validate()?;
        if cfg.alloc.0 + cfg.alloc.1 + cfg.alloc.2 != blocks.len() {
            bail!("allocation does not cover the {} blocks", blocks.len());
        }
        let bank = RomBank::new(blocks, ordering, cfg.alloc)?;
        let tm = MultiTm::new(&cfg.shape)?;
        let mut memmgr = MemoryManager::new(&cfg.shape);
        let mut online = OnlineInputPath::new(
            &cfg.shape,
            cfg.online_buffer_capacity,
            cfg.online_production_interval,
        );
        if let Some(class) = cfg.initial_filter {
            memmgr.filter = ClassFilter::removing(class);
            online.filter = ClassFilter::removing(class);
        }
        let mut regs = RegisterFile::new();
        // MCU programs the run-time registers at start-up (§3.8).
        regs.write_s_param(cfg.s_offline);
        regs.write(Reg::TParam, cfg.t as u32);
        regs.write(Reg::ClauseNum, cfg.active_clauses as u32);
        regs.write(Reg::ClassNum, cfg.active_classes as u32);
        if let Some(c) = cfg.initial_filter {
            regs.write(Reg::FilterClass, c as u32);
        }
        let mut ctrl_v = ctrl::START;
        if cfg.online_learning {
            ctrl_v |= ctrl::ONLINE_ENABLE;
        }
        if cfg.initial_filter.is_some() {
            ctrl_v |= ctrl::FILTER_ENABLE;
        }
        regs.write(Reg::Ctrl, ctrl_v);

        let mut rng = Xoshiro256::new(cfg.seed);
        // The seeded scratch consumes the same construction-time draw the
        // old StepRands field did, so existing run trajectories (and the
        // figure suites pinned to them) are unchanged.
        let scratch = TrainScratch::seeded(&mut rng, &cfg.shape);
        let hl = HighLevelManager::new(cfg.offline_epochs, cfg.online_iterations);
        Ok(FpgaSystem {
            online_learning: cfg.online_learning,
            analyzer: AccuracyAnalyzer::new(cfg.history_mode),
            fault_ctl: FaultController::new(&cfg.shape),
            mcu: Mcu::new(cfg.mcu_handshake_latency, cfg.axi_write_cost),
            engine: DatapointEngine::new(),
            clock: Clock::new(),
            regs,
            handshake_stats: HandshakeStats::default(),
            tm,
            memmgr,
            bank,
            online,
            hl,
            rng,
            scratch,
            cfg,
        })
    }

    fn params(&self, online: bool) -> TmParams {
        TmParams {
            s: if online { self.cfg.s_online } else { self.regs.s_param() },
            t: self.regs.peek(Reg::TParam) as i32,
            active_clauses: self.regs.peek(Reg::ClauseNum) as usize,
            active_classes: self.regs.peek(Reg::ClassNum) as usize,
            boost_true_positive: false,
            s_style: crate::tm::params::SStyle::InactionBiased,
        }
    }

    /// One offline training epoch: stream the (filtered, truncated)
    /// offline set through the pipelined train datapath.
    fn offline_epoch(&mut self) -> Result<()> {
        let params = self.params(false);
        let (rows, mem_cycles) = self.memmgr.stream(
            &mut self.bank,
            SetId::OfflineTrain,
            Port::A,
            self.cfg.offline_train_len,
        )?;
        let compute = DatapointEngine::pipelined_cycles(rows.len());
        let cycles = mem_cycles.max(compute);
        self.clock.set_enabled(Module::TmCore, true);
        self.clock.with_enabled(Module::Management, |c| {
            c.with_enabled(Module::OfflineMemory, |c| c.advance(cycles))
        });
        self.clock.set_enabled(Module::TmCore, false);
        let shape = self.cfg.shape.clone();
        // Lane-speculative training (bit-identical to the historical
        // per-step refill + train_step_fast loop — figures are
        // unchanged); switching activity is toggled in aggregate, which
        // the activity counters accumulate identically.
        let planes = BitPlanes::from_labelled(&shape, &rows);
        let stats =
            train_rows_seq(&mut self.tm, &rows, &planes, &params, &mut self.rng, &mut self.scratch);
        self.clock.toggle(Module::TmCore, stats.activity.total_updates() as u64);
        self.engine.processed += stats.steps as u64;
        Ok(())
    }

    /// Accuracy analysis across the configured sets; the online source
    /// keeps producing into the cyclic buffer meanwhile (§3.5.2).
    fn analysis(&mut self, iteration: usize) -> Result<Vec<AccuracyRecord>> {
        let params = self.params(false);
        let mut sets = vec![SetId::OfflineTrain];
        if self.cfg.analyze_validation {
            sets.push(SetId::Validation);
        }
        if self.cfg.analyze_online {
            sets.push(SetId::OnlineTrain);
        }
        let mut out = Vec::new();
        for set in sets {
            let t0 = self.clock.now();
            let rec = self.analyzer.analyze(
                &mut self.tm,
                &params,
                &self.memmgr,
                &mut self.bank,
                set,
                iteration,
                &mut self.clock,
            )?;
            // Report registers + handshake to the MCU (offload mode).
            self.regs.set(Reg::AccErrors, rec.errors as u32);
            self.regs.set(Reg::AccTotal, rec.total as u32);
            self.regs.set(Reg::AccSet, set as u32);
            self.regs.set(Reg::AccIteration, iteration as u32);
            if self.analyzer.mode == HistoryMode::OffloadToMcu {
                let stall = self.mcu.receive_report(rec);
                handshake(&mut self.regs, &mut self.handshake_stats, stall)?;
                self.clock
                    .with_enabled(Module::AxiInterface, |c| c.advance(stall));
            } else {
                self.mcu.receive_report(rec);
            }
            // Wall-clock passed; the online parser kept producing.
            let elapsed = self.clock.now() - t0;
            self.online.advance(elapsed, &mut self.bank)?;
            out.push(rec);
        }
        Ok(out)
    }

    /// Apply one MCU action (costing AXI cycles) before an online pass.
    fn apply_action(&mut self, action: &McuAction) -> Result<()> {
        let cost = self.mcu.action_cost(action);
        self.clock
            .with_enabled(Module::AxiInterface, |c| c.advance(cost));
        match action {
            McuAction::SetFilter { enabled, class } => {
                self.regs.write(Reg::FilterClass, *class as u32);
                self.regs.set_bit(Reg::Ctrl, ctrl::FILTER_ENABLE, *enabled);
                let f = if *enabled {
                    ClassFilter::removing(*class)
                } else {
                    ClassFilter::disabled()
                };
                self.memmgr.filter = f;
                self.online.filter = f;
            }
            McuAction::SetOnlineLearning(on) => {
                self.regs.set_bit(Reg::Ctrl, ctrl::ONLINE_ENABLE, *on);
                self.online_learning = *on;
            }
            McuAction::InjectFaults(map) => {
                self.clock
                    .toggle(Module::FaultController, map.count() as u64);
                self.fault_ctl.load_map(map.clone());
                self.tm.set_fault_map(self.fault_ctl.map().clone());
            }
            McuAction::InjectClauseFaults(list) => {
                self.clock
                    .toggle(Module::FaultController, list.len() as u64);
                for (c, j, force) in list {
                    self.tm.set_clause_fault(*c, *j, *force);
                }
            }
            McuAction::SetActiveClauses(n) => {
                self.regs.write(Reg::ClauseNum, *n as u32);
            }
            McuAction::SetActiveClasses(n) => {
                self.regs.write(Reg::ClassNum, *n as u32);
            }
            McuAction::SetS(s) => self.regs.write_s_param(*s),
            McuAction::SetT(t) => self.regs.write(Reg::TParam, *t as u32),
        }
        Ok(())
    }

    /// One online-learning pass (§4: "online learning is then executed
    /// for a set number of datapoints").
    fn online_pass(&mut self) -> Result<()> {
        let n = match self.cfg.online_pass_len {
            Some(n) => n,
            None => self.memmgr_len_online()?,
        };
        if !self.online_learning {
            // Learning disabled (Figs 6/8 baselines): the TM idles while
            // the same wall-clock of data arrives; the buffer absorbs what
            // it can and drops the rest.
            let wait = n as u64 * self.cfg.online_production_interval;
            self.clock
                .with_enabled(Module::OnlineInput, |c| c.advance(wait));
            self.online.advance(wait, &mut self.bank)?;
            // Discard buffered rows (they were never consumed).
            while self.online.buffer.pop().is_some() {}
            return Ok(());
        }
        let params = self.params(true);
        // Consume n rows: buffered first, then direct — the TM sustains
        // one datapoint/clock; if the source is slower we stall on
        // production.
        let buffered = self.online.buffer.len();
        let produced_live = n.saturating_sub(buffered);
        let production_cycles = produced_live as u64 * self.cfg.online_production_interval;
        let compute_cycles = DatapointEngine::pipelined_cycles(n);
        let busy = compute_cycles.min(production_cycles.max(compute_cycles));
        // TM core is busy for the compute portion; waiting-on-data cycles
        // leave it gated (clock gating saves power, §6).
        self.clock.set_enabled(Module::TmCore, true);
        self.clock.with_enabled(Module::Management, |c| {
            c.with_enabled(Module::OnlineInput, |c| c.advance(compute_cycles))
        });
        self.clock.set_enabled(Module::TmCore, false);
        if production_cycles > compute_cycles {
            self.clock
                .with_enabled(Module::OnlineInput, |c| c.advance(production_cycles - compute_cycles));
        }
        let _ = busy;
        let shape = self.cfg.shape.clone();
        // Drain the pass's rows first (the source and cyclic buffer are
        // independent of training), then lane-train them in one batch —
        // same per-row refill order, bit-identical trajectory.
        let mut rows: Vec<(crate::tm::clause::Input, usize)> = Vec::with_capacity(n);
        for _ in 0..n {
            let Some((x, y)) = self.online.request(&mut self.bank)? else {
                break; // source fully filtered/dry
            };
            rows.push((x, y));
        }
        let planes = BitPlanes::from_labelled(&shape, &rows);
        let stats =
            train_rows_seq(&mut self.tm, &rows, &planes, &params, &mut self.rng, &mut self.scratch);
        self.clock.toggle(Module::TmCore, stats.activity.total_updates() as u64);
        self.engine.processed += stats.steps as u64;
        Ok(())
    }

    fn memmgr_len_online(&mut self) -> Result<usize> {
        // Length of one filtered online pass (the RTL derives this from
        // the filter's pass-count port).
        let f = self.online.filter;
        let mm = MemoryManager { shape: self.cfg.shape.clone(), filter: f };
        mm.filtered_len(&mut self.bank, SetId::OnlineTrain)
    }

    /// Execute the full Fig-3 flow.
    pub fn run(&mut self) -> Result<RunReport> {
        let points = self.cfg.online_iterations + 1;
        let mut offline_curve = vec![f64::NAN; points];
        let mut validation_curve = vec![f64::NAN; points];
        let mut online_curve = vec![f64::NAN; points];

        self.hl.advance(Event::Start)?;
        loop {
            match self.hl.phase() {
                Phase::OfflineTraining { .. } => {
                    self.offline_epoch()?;
                    self.hl.advance(Event::EpochDone)?;
                }
                Phase::Analysis { iteration } => {
                    for rec in self.analysis(iteration)? {
                        let curve = match rec.set {
                            SetId::OfflineTrain => &mut offline_curve,
                            SetId::Validation => &mut validation_curve,
                            SetId::OnlineTrain => &mut online_curve,
                        };
                        curve[iteration] = rec.accuracy();
                    }
                    self.hl.advance(Event::AnalysisDone)?;
                }
                Phase::OnlineLearning { iteration } => {
                    for action in self.mcu.due_actions(iteration) {
                        self.apply_action(&action)?;
                    }
                    self.online_pass()?;
                    self.hl.advance(Event::OnlinePassDone)?;
                }
                Phase::Halted => break,
                Phase::Idle => bail!("FSM stuck in Idle"),
            }
        }
        let power = self.cfg.power.estimate(&self.clock);
        Ok(RunReport {
            offline_curve,
            validation_curve,
            online_curve,
            total_cycles: self.clock.now(),
            handshake: self.handshake_stats,
            dropped_datapoints: self.online.dropped(),
            power,
            records: self.mcu.reports.clone(),
            uart_log: self.mcu.uart_log.clone(),
            tm_toggles: self.clock.activity(Module::TmCore).toggle_events,
            rescore: self.analyzer.rescore_stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockPlan;
    use crate::data::iris;

    pub(crate) fn iris_blocks() -> Vec<BoolDataset> {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
        (0..5).map(|i| plan.block(i).clone()).collect()
    }

    #[test]
    fn paper_config_runs_end_to_end() {
        let mut cfg = SystemConfig::paper();
        cfg.online_iterations = 4; // keep the unit test quick
        let blocks = iris_blocks();
        let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let rep = sys.run().unwrap();
        assert_eq!(rep.offline_curve.len(), 5);
        assert!(rep.offline_curve.iter().all(|a| a.is_finite()));
        assert!(rep.offline_curve[0] > 0.5, "offline training learned something");
        assert!(rep.total_cycles > 0);
        // 3 sets × 5 analysis points offloaded.
        assert_eq!(rep.records.len(), 15);
        assert_eq!(rep.handshake.transactions, 15);
        assert_eq!(rep.uart_log.len(), 15);
        // The analyses ran through the incremental re-scorer: 3 cold
        // builds (one per set), the remaining 12 incremental, with some
        // clauses served clean (training never flips all 48 every pass).
        assert_eq!(rep.rescore.cold_builds, 3);
        assert_eq!(rep.rescore.evaluations, 12);
        assert!(rep.rescore.clean_clauses > 0);
        let f = rep.rescore.dirty_fraction();
        assert!((0.0..=1.0).contains(&f), "dirty fraction {f}");
        // Paper power envelope.
        assert!(rep.power.total_w > 1.4 && rep.power.total_w < 2.0);
    }

    #[test]
    fn online_learning_improves_online_curve() {
        let mut cfg = SystemConfig::paper();
        cfg.online_iterations = 8;
        let blocks = iris_blocks();
        let mut sys = FpgaSystem::new(cfg, &blocks, &[2, 0, 1, 4, 3]).unwrap();
        let rep = sys.run().unwrap();
        let first = rep.online_curve[0];
        let last = rep.online_curve[8];
        assert!(last > first, "online acc {first:.3} -> {last:.3} should rise");
    }

    #[test]
    fn disabled_online_learning_freezes_machine() {
        let mut cfg = SystemConfig::paper();
        cfg.online_iterations = 3;
        cfg.online_learning = false;
        let blocks = iris_blocks();
        let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let rep = sys.run().unwrap();
        for it in 1..=3 {
            assert_eq!(rep.offline_curve[it], rep.offline_curve[0]);
            assert_eq!(rep.online_curve[it], rep.online_curve[0]);
        }
        // Idle waiting drops datapoints once the buffer fills.
        assert!(rep.dropped_datapoints > 0);
    }

    #[test]
    fn mcu_schedule_applies_actions() {
        use crate::tm::fault::{Fault, FaultMap};
        let mut cfg = SystemConfig::paper();
        cfg.online_iterations = 4;
        let shape = cfg.shape.clone();
        let blocks = iris_blocks();
        let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let map = FaultMap::even_spread(&shape, 0.2, Fault::StuckAt0, 9).unwrap();
        sys.mcu.schedule(3, McuAction::InjectFaults(map.clone()));
        let rep = sys.run().unwrap();
        assert_eq!(sys.tm.fault().count(), map.count());
        assert_eq!(sys.fault_ctl.programmed, shape.num_tas() as u64);
        // Accuracy at iteration 3+ reflects the faults (almost surely
        // different from iteration 2).
        let _ = rep;
    }

    #[test]
    fn initial_filter_reduces_analysis_totals() {
        let mut cfg = SystemConfig::paper();
        cfg.online_iterations = 1;
        cfg.initial_filter = Some(0);
        let blocks = iris_blocks();
        let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let rep = sys.run().unwrap();
        let offline = rep.records.iter().find(|r| r.set == SetId::OfflineTrain).unwrap();
        let val = rep.records.iter().find(|r| r.set == SetId::Validation).unwrap();
        assert_eq!(offline.total, 20, "paper §5.2: 30 -> 20 after filtering");
        assert_eq!(val.total, 40, "paper §5.2: 60 -> 40 after filtering");
    }

    #[test]
    fn handshake_stalls_are_the_only_axi_cost() {
        let mut cfg = SystemConfig::paper();
        cfg.online_iterations = 2;
        // Buffer big enough that MCU speed cannot cause data loss — we
        // isolate the pure handshake-stall effect here (overflow-induced
        // loss under slow MCUs is covered by disabled_online_learning).
        cfg.online_buffer_capacity = 4096;
        cfg.mcu_handshake_latency = 100;
        let blocks = iris_blocks();
        let mut sys = FpgaSystem::new(cfg.clone(), &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let slow = sys.run().unwrap();
        cfg.mcu_handshake_latency = 1;
        let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let fast = sys.run().unwrap();
        let d_stall = slow.handshake.stall_cycles - fast.handshake.stall_cycles;
        // §6: MCU speed slows the system only through handshake stalls.
        // (Longer stalls also pre-fill the online buffer further, hiding
        // some production wait, so the total delta is bounded by — not
        // equal to — the stall delta.)
        let d_total = slow.total_cycles - fast.total_cycles;
        assert!(
            d_total <= d_stall && d_total > 0,
            "cycle delta {d_total} should be positive and ≤ stall delta {d_stall}"
        );
        // Curves identical: MCU speed never changes results.
        assert_eq!(slow.offline_curve, fast.offline_curve);
    }
}
