//! Cycle-level online data input path (paper §3.5).
//!
//! The input-parser IP pulls rows of the online-training set out of the
//! dual-port ROM (port B, so accuracy analysis can use port A
//! concurrently, §3.6.2) at a configurable *production rate* — modelling
//! an external sensor/UART source. Rows land in the cyclic buffer so
//! "datapoints [are not] ignored by the system during accuracy analysis";
//! the online data manager serves them to TM management on request.

use crate::data::filter::ClassFilter;
use crate::data::online::CyclicBuffer;
use crate::fpga::rom::{Port, RomBank, SetId};
use crate::tm::clause::Input;
use crate::tm::params::TmShape;
use anyhow::Result;

/// The cycle-level online input path.
#[derive(Debug, Clone)]
pub struct OnlineInputPath {
    shape: TmShape,
    /// The parser produces one row every `production_interval` cycles.
    pub production_interval: u64,
    /// Cycles accumulated toward the next production.
    accum: u64,
    /// Parser position in the online set (wraps — cyclic source).
    pos: usize,
    pub buffer: CyclicBuffer<(Input, usize)>,
    pub filter: ClassFilter,
    /// Rows produced by the parser so far.
    pub produced: u64,
    /// Rows served to TM management.
    pub served: u64,
}

impl OnlineInputPath {
    pub fn new(shape: &TmShape, buffer_capacity: usize, production_interval: u64) -> Self {
        OnlineInputPath {
            shape: shape.clone(),
            production_interval: production_interval.max(1),
            accum: 0,
            pos: 0,
            buffer: CyclicBuffer::new(buffer_capacity),
            filter: ClassFilter::disabled(),
            produced: 0,
            served: 0,
        }
    }

    /// Parser reads the next passing row from ROM port B (wrapping).
    fn parse_next(&mut self, bank: &mut RomBank) -> Result<Option<(Input, usize)>> {
        let len = bank.set_len(SetId::OnlineTrain);
        for _ in 0..len {
            let row = self.pos;
            self.pos = (self.pos + 1) % len;
            let ((bits, label), _c) = bank.read(SetId::OnlineTrain, row, Port::B)?;
            if self.filter.passes(label) {
                return Ok(Some((Input::pack(&self.shape, &bits), label)));
            }
        }
        Ok(None) // everything filtered
    }

    /// Let `cycles` of wall-clock pass while the TM is busy elsewhere:
    /// the parser keeps producing into the buffer (overflow counted
    /// there).
    pub fn advance(&mut self, cycles: u64, bank: &mut RomBank) -> Result<()> {
        self.accum += cycles;
        while self.accum >= self.production_interval {
            self.accum -= self.production_interval;
            if let Some(row) = self.parse_next(bank)? {
                self.produced += 1;
                self.buffer.push(row);
            } else {
                break;
            }
        }
        Ok(())
    }

    /// TM management requests one datapoint: buffered rows first, else a
    /// direct parser read (the TM is faster than the source, §6).
    pub fn request(&mut self, bank: &mut RomBank) -> Result<Option<(Input, usize)>> {
        let row = match self.buffer.pop() {
            Some(r) => Some(r),
            None => {
                let r = self.parse_next(bank)?;
                if r.is_some() {
                    self.produced += 1;
                }
                r
            }
        };
        if row.is_some() {
            self.served += 1;
        }
        Ok(row)
    }

    /// Datapoints lost to buffer overflow.
    pub fn dropped(&self) -> usize {
        self.buffer.dropped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockPlan;
    use crate::data::dataset::BoolDataset;
    use crate::data::iris;

    fn bank() -> RomBank {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 1).unwrap();
        let blocks: Vec<BoolDataset> = (0..5).map(|i| plan.block(i).clone()).collect();
        RomBank::new(&blocks, &[0, 1, 2, 3, 4], (1, 2, 2)).unwrap()
    }

    fn path() -> OnlineInputPath {
        OnlineInputPath::new(&TmShape::iris(), 16, 4)
    }

    #[test]
    fn produces_at_configured_rate() {
        let mut p = path();
        let mut b = bank();
        p.advance(16, &mut b).unwrap(); // 16 cycles / interval 4 = 4 rows
        assert_eq!(p.produced, 4);
        assert_eq!(p.buffer.len(), 4);
        p.advance(3, &mut b).unwrap(); // not enough for another
        assert_eq!(p.produced, 4);
        p.advance(1, &mut b).unwrap();
        assert_eq!(p.produced, 5);
    }

    #[test]
    fn request_serves_buffer_then_direct() {
        let mut p = path();
        let mut b = bank();
        p.advance(8, &mut b).unwrap(); // 2 buffered
        let first = p.request(&mut b).unwrap().unwrap();
        // Ordering preserved: first buffered row is online row 0.
        let ((bits0, label0), _) = b.read(SetId::OnlineTrain, 0, Port::A).unwrap();
        assert_eq!(first.1, label0);
        assert_eq!(first.0, Input::pack(&TmShape::iris(), &bits0));
        p.request(&mut b).unwrap().unwrap();
        assert!(p.buffer.is_empty());
        // Direct read continues the sequence (row 2).
        let third = p.request(&mut b).unwrap().unwrap();
        let ((bits2, _), _) = b.read(SetId::OnlineTrain, 2, Port::A).unwrap();
        assert_eq!(third.0, Input::pack(&TmShape::iris(), &bits2));
        assert_eq!(p.served, 3);
    }

    #[test]
    fn buffer_overflow_drops_and_counts() {
        let mut p = OnlineInputPath::new(&TmShape::iris(), 4, 1);
        let mut b = bank();
        p.advance(10, &mut b).unwrap();
        assert_eq!(p.buffer.len(), 4);
        assert_eq!(p.dropped(), 6);
    }

    #[test]
    fn filter_skips_class_and_lifts() {
        let mut p = path();
        p.filter = ClassFilter::removing(0);
        let mut b = bank();
        for _ in 0..10 {
            let (_x, label) = p.request(&mut b).unwrap().unwrap();
            assert_ne!(label, 0, "class 0 filtered (§5.2)");
        }
        p.filter.set_enabled(false);
        // The unseen class eventually appears.
        let mut saw0 = false;
        for _ in 0..60 {
            if p.request(&mut b).unwrap().unwrap().1 == 0 {
                saw0 = true;
                break;
            }
        }
        assert!(saw0, "lifting the filter admits the new class");
    }

    #[test]
    fn wraps_around_the_online_set() {
        let mut p = path();
        let mut b = bank();
        let mut labels = Vec::new();
        for _ in 0..120 {
            labels.push(p.request(&mut b).unwrap().unwrap().1);
        }
        assert_eq!(&labels[..60], &labels[60..], "second pass identical");
    }
}
