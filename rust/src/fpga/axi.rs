//! System/microcontroller interface (paper §3.7): a bank of 32-bit I/O
//! registers exposed over AXI, plus the handshaking protocol that decouples
//! fabric speed from microcontroller speed.
//!
//! "The IP sends a signal to the microcontroller informing it that certain
//! registers are ready to be read from, then pauses the system whilst
//! waiting for the microcontroller to respond." — the handshake model
//! counts those stall cycles; §6 notes they are the system's only
//! slowdown.

use anyhow::{bail, Result};

/// Register map (word indices). Mirrors the paper's "more specific IP to
/// separate and combine signals into these registers".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Reg {
    /// Control: bit0 start, bit1 online-learning enable, bit2 filter enable.
    Ctrl = 0,
    /// Specificity `s` (IEEE-754 f32 bits) — runtime port (§3.1).
    SParam = 1,
    /// Threshold `T` (integer).
    TParam = 2,
    /// Clause-number port (§3.1.1).
    ClauseNum = 3,
    /// Active-class count (over-provisioned classes).
    ClassNum = 4,
    /// Class filtered by the class-filter IP (§3.4.1).
    FilterClass = 5,
    /// Status: bit0 busy, bit1 report-valid.
    Status = 6,
    /// Accuracy report: error count.
    AccErrors = 7,
    /// Accuracy report: datapoints analysed.
    AccTotal = 8,
    /// Accuracy report: which set (0 offline / 1 validation / 2 online).
    AccSet = 9,
    /// Accuracy report: online iteration index.
    AccIteration = 10,
    /// Fault controller: TA address (flat index).
    FaultAddr = 11,
    /// Fault controller: mapping (0 none / 1 stuck-at-0 / 2 stuck-at-1);
    /// writing strobes the controller.
    FaultData = 12,
}

pub const NUM_REGS: usize = 16;

/// Control-register bits.
pub mod ctrl {
    pub const START: u32 = 1 << 0;
    pub const ONLINE_ENABLE: u32 = 1 << 1;
    pub const FILTER_ENABLE: u32 = 1 << 2;
}

/// Status-register bits.
pub mod status {
    pub const BUSY: u32 = 1 << 0;
    pub const REPORT_VALID: u32 = 1 << 1;
}

/// The AXI-mapped register file.
#[derive(Debug, Clone)]
pub struct RegisterFile {
    regs: [u32; NUM_REGS],
    pub reads: u64,
    pub writes: u64,
}

impl Default for RegisterFile {
    fn default() -> Self {
        Self::new()
    }
}

impl RegisterFile {
    pub fn new() -> Self {
        RegisterFile { regs: [0; NUM_REGS], reads: 0, writes: 0 }
    }

    pub fn read(&mut self, r: Reg) -> u32 {
        self.reads += 1;
        self.regs[r as usize]
    }

    /// Peek without counting a bus transaction (fabric-side wiring).
    pub fn peek(&self, r: Reg) -> u32 {
        self.regs[r as usize]
    }

    pub fn write(&mut self, r: Reg, v: u32) {
        self.writes += 1;
        self.regs[r as usize] = v;
    }

    /// Fabric-side update (no bus transaction).
    pub fn set(&mut self, r: Reg, v: u32) {
        self.regs[r as usize] = v;
    }

    pub fn set_bit(&mut self, r: Reg, bit: u32, on: bool) {
        let v = self.peek(r);
        self.set(r, if on { v | bit } else { v & !bit });
    }

    pub fn s_param(&self) -> f32 {
        f32::from_bits(self.peek(Reg::SParam))
    }

    pub fn write_s_param(&mut self, s: f32) {
        self.write(Reg::SParam, s.to_bits());
    }
}

/// Handshake statistics: every report transaction stalls the fabric for
/// the MCU's response latency.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HandshakeStats {
    pub transactions: u64,
    pub stall_cycles: u64,
}

/// One handshake: fabric raises report-valid, waits `mcu_latency` cycles
/// for the MCU to read and acknowledge, then clears and resumes.
/// Returns the stall cycles consumed.
pub fn handshake(
    regs: &mut RegisterFile,
    stats: &mut HandshakeStats,
    mcu_latency: u64,
) -> Result<u64> {
    if regs.peek(Reg::Status) & status::REPORT_VALID != 0 {
        bail!("handshake re-entered while a report is pending");
    }
    regs.set_bit(Reg::Status, status::REPORT_VALID, true);
    // ... MCU reads the report registers and acknowledges ...
    regs.set_bit(Reg::Status, status::REPORT_VALID, false);
    stats.transactions += 1;
    stats.stall_cycles += mcu_latency;
    Ok(mcu_latency)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_roundtrip_and_counters() {
        let mut rf = RegisterFile::new();
        rf.write(Reg::TParam, 15);
        assert_eq!(rf.read(Reg::TParam), 15);
        assert_eq!(rf.reads, 1);
        assert_eq!(rf.writes, 1);
        rf.set(Reg::AccErrors, 3); // fabric-side, no transaction
        assert_eq!(rf.peek(Reg::AccErrors), 3);
        assert_eq!(rf.writes, 1);
    }

    #[test]
    fn s_param_f32_bits() {
        let mut rf = RegisterFile::new();
        rf.write_s_param(1.375);
        assert_eq!(rf.s_param(), 1.375);
        rf.write_s_param(1.0);
        assert_eq!(rf.s_param(), 1.0);
    }

    #[test]
    fn ctrl_bits() {
        let mut rf = RegisterFile::new();
        rf.write(Reg::Ctrl, ctrl::START | ctrl::ONLINE_ENABLE);
        assert_ne!(rf.peek(Reg::Ctrl) & ctrl::START, 0);
        assert_ne!(rf.peek(Reg::Ctrl) & ctrl::ONLINE_ENABLE, 0);
        assert_eq!(rf.peek(Reg::Ctrl) & ctrl::FILTER_ENABLE, 0);
        rf.set_bit(Reg::Ctrl, ctrl::ONLINE_ENABLE, false);
        assert_eq!(rf.peek(Reg::Ctrl) & ctrl::ONLINE_ENABLE, 0);
    }

    #[test]
    fn handshake_counts_stalls_and_clears_valid() {
        let mut rf = RegisterFile::new();
        let mut hs = HandshakeStats::default();
        let stall = handshake(&mut rf, &mut hs, 25).unwrap();
        assert_eq!(stall, 25);
        assert_eq!(hs.transactions, 1);
        assert_eq!(hs.stall_cycles, 25);
        assert_eq!(rf.peek(Reg::Status) & status::REPORT_VALID, 0);
        handshake(&mut rf, &mut hs, 25).unwrap();
        assert_eq!(hs.transactions, 2);
        assert_eq!(hs.stall_cycles, 50);
    }

    #[test]
    fn handshake_rejects_reentry() {
        let mut rf = RegisterFile::new();
        let mut hs = HandshakeStats::default();
        rf.set_bit(Reg::Status, status::REPORT_VALID, true);
        assert!(handshake(&mut rf, &mut hs, 10).is_err());
    }
}
