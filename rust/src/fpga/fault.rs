//! Fault controller (paper §3.1.2): the addressable module holding the
//! per-TA AND/OR gate mappings, programmable from the microcontroller over
//! AXI "without re-synthesis of the FPGA logic".
//!
//! Address encoding (the `FaultAddr` register): the flat TA index
//! `(class * max_clauses + clause) * literals + literal` — the same
//! row-major layout every other layer uses.

use crate::fpga::axi::{Reg, RegisterFile};
use crate::tm::fault::{Fault, FaultMap};
use crate::tm::params::TmShape;
use anyhow::{bail, Result};

/// Mapping codes used on the `FaultData` register.
pub const FAULT_NONE: u32 = 0;
pub const FAULT_STUCK_AT_0: u32 = 1;
pub const FAULT_STUCK_AT_1: u32 = 2;

/// The fault controller: decodes AXI writes into [`FaultMap`] updates.
#[derive(Debug, Clone)]
pub struct FaultController {
    shape: TmShape,
    map: FaultMap,
    /// Programmed writes so far (diagnostics).
    pub programmed: u64,
}

impl FaultController {
    pub fn new(shape: &TmShape) -> Self {
        FaultController {
            shape: shape.clone(),
            map: FaultMap::none(shape),
            programmed: 0,
        }
    }

    pub fn map(&self) -> &FaultMap {
        &self.map
    }

    /// Decode a flat TA address.
    pub fn decode(&self, addr: u32) -> Result<(usize, usize, usize)> {
        let lits = self.shape.literals();
        let addr = addr as usize;
        if addr >= self.shape.num_tas() {
            bail!("TA address {addr} out of range ({} TAs)", self.shape.num_tas());
        }
        let lit = addr % lits;
        let clause = (addr / lits) % self.shape.max_clauses;
        let class = addr / (lits * self.shape.max_clauses);
        Ok((class, clause, lit))
    }

    /// Program one TA mapping directly.
    pub fn program(&mut self, addr: u32, data: u32) -> Result<()> {
        let (c, j, k) = self.decode(addr)?;
        let fault = match data {
            FAULT_NONE => Fault::None,
            FAULT_STUCK_AT_0 => Fault::StuckAt0,
            FAULT_STUCK_AT_1 => Fault::StuckAt1,
            _ => bail!("bad fault code {data}"),
        };
        self.map.set(c, j, k, fault);
        self.programmed += 1;
        Ok(())
    }

    /// Service a strobed AXI write: reads `FaultAddr`/`FaultData` from the
    /// register file and programs the mapping.
    pub fn service_axi(&mut self, regs: &RegisterFile) -> Result<()> {
        self.program(regs.peek(Reg::FaultAddr), regs.peek(Reg::FaultData))
    }

    /// Load a whole map at once (the experiment driver's bulk path — the
    /// paper used a python script generating one write per TA; cost
    /// accounting for that is handled by the caller via `programmed`).
    pub fn load_map(&mut self, map: FaultMap) {
        self.map = map;
        self.programmed += self.shape.num_tas() as u64;
    }

    /// Clear every mapping to fault-free.
    pub fn clear(&mut self) {
        self.map = FaultMap::none(&self.shape);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    #[test]
    fn decode_roundtrip() {
        let fc = FaultController::new(&shape());
        assert_eq!(fc.decode(0).unwrap(), (0, 0, 0));
        assert_eq!(fc.decode(31).unwrap(), (0, 0, 31));
        assert_eq!(fc.decode(32).unwrap(), (0, 1, 0));
        assert_eq!(fc.decode(16 * 32).unwrap(), (1, 0, 0));
        assert_eq!(fc.decode(3 * 16 * 32 - 1).unwrap(), (2, 15, 31));
        assert!(fc.decode(3 * 16 * 32).is_err());
    }

    #[test]
    fn program_and_clear() {
        let mut fc = FaultController::new(&shape());
        fc.program(5, FAULT_STUCK_AT_0).unwrap();
        fc.program(40, FAULT_STUCK_AT_1).unwrap();
        assert_eq!(fc.map().get(0, 0, 5), Fault::StuckAt0);
        assert_eq!(fc.map().get(0, 1, 8), Fault::StuckAt1);
        assert_eq!(fc.programmed, 2);
        fc.program(5, FAULT_NONE).unwrap();
        assert_eq!(fc.map().get(0, 0, 5), Fault::None);
        fc.clear();
        assert!(fc.map().is_fault_free());
    }

    #[test]
    fn bad_code_rejected() {
        let mut fc = FaultController::new(&shape());
        assert!(fc.program(0, 3).is_err());
    }

    #[test]
    fn service_axi_reads_registers() {
        let mut fc = FaultController::new(&shape());
        let mut rf = RegisterFile::new();
        rf.write(Reg::FaultAddr, 100);
        rf.write(Reg::FaultData, FAULT_STUCK_AT_1);
        fc.service_axi(&rf).unwrap();
        let (c, j, k) = fc.decode(100).unwrap();
        assert_eq!(fc.map().get(c, j, k), Fault::StuckAt1);
    }

    #[test]
    fn load_map_bulk() {
        let mut fc = FaultController::new(&shape());
        let m = FaultMap::even_spread(&shape(), 0.2, Fault::StuckAt0, 1).unwrap();
        let count = m.count();
        fc.load_map(m);
        assert_eq!(fc.map().count(), count);
        assert_eq!(fc.programmed, shape().num_tas() as u64);
    }
}
