//! Activity-based power model (paper §6).
//!
//! The paper reports **1.725 W** total on the Zybo Z7-20, of which
//! **1.4 W** is the on-board microcontroller (Zynq PS, default tool
//! activity), leaving ≈ 0.325 W for the programmable fabric. Clock gating
//! "provid[es] significant power consumption improvements" when the TM is
//! idle and for over-provisioned clauses/TAs.
//!
//! Model: `P = P_mcu + P_static + Σ_m (C_clk[m]·duty[m] + E_tog[m]·rate[m])·V²f`
//! folded into per-module coefficients calibrated so the paper's
//! experimental configuration lands on the paper's numbers:
//!
//! - per-module *clock-tree/активity* power applies only to enabled cycles
//!   (gated cycles cost the residual leakage inside `P_static`);
//! - per-event switching energy applies to recorded toggle events.

use crate::fpga::clock::{Clock, Module, ALL_MODULES};

/// Power coefficients (Watts at 100 MHz reference clock).
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Clock frequency (Hz) — scales the dynamic terms.
    pub f_clk_hz: f64,
    /// Microcontroller (Zynq PS) baseline.
    pub mcu_w: f64,
    /// Fabric static (leakage + always-on clock backbone).
    pub static_w: f64,
    /// Per-module dynamic power when the module's clock is enabled, at
    /// the reference frequency (W).
    pub module_active_w: fn(Module) -> f64,
    /// Energy per toggle event (J).
    pub toggle_j: f64,
}

/// Calibrated per-module active power (W at 100 MHz). The TM core
/// dominates the fabric; management/analysis/memory are small FSMs.
fn default_module_active_w(m: Module) -> f64 {
    match m {
        Module::TmCore => 0.140,
        Module::TmOverProvision => 0.030,
        Module::Management => 0.015,
        Module::AccuracyAnalysis => 0.010,
        Module::OfflineMemory => 0.020,
        Module::OnlineInput => 0.010,
        Module::AxiInterface => 0.008,
        Module::FaultController => 0.004,
    }
}

pub const REFERENCE_CLK_HZ: f64 = 100.0e6;

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            f_clk_hz: REFERENCE_CLK_HZ,
            mcu_w: 1.40,
            static_w: 0.105,
            module_active_w: default_module_active_w,
            toggle_j: 2.0e-11,
        }
    }
}

/// Power estimate for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerReport {
    pub total_w: f64,
    pub mcu_w: f64,
    pub fabric_w: f64,
    pub static_w: f64,
    /// (module, average W) breakdown of the dynamic fabric power.
    pub per_module_w: Vec<(Module, f64)>,
}

impl PowerModel {
    /// Estimate average power over the recorded activity window.
    pub fn estimate(&self, clock: &Clock) -> PowerReport {
        let total_cycles = clock.now().max(1) as f64;
        let f_scale = self.f_clk_hz / REFERENCE_CLK_HZ;
        let seconds = total_cycles / self.f_clk_hz;
        let mut per_module = Vec::new();
        let mut dynamic = 0.0;
        for m in ALL_MODULES {
            let a = clock.activity(m);
            let duty = a.active_cycles as f64 / total_cycles;
            let clk_w = (self.module_active_w)(m) * duty * f_scale;
            let tog_w = a.toggle_events as f64 * self.toggle_j / seconds.max(1e-12);
            per_module.push((m, clk_w + tog_w));
            dynamic += clk_w + tog_w;
        }
        let fabric = self.static_w + dynamic;
        PowerReport {
            total_w: self.mcu_w + fabric,
            mcu_w: self.mcu_w,
            fabric_w: fabric,
            static_w: self.static_w,
            per_module_w: per_module,
        }
    }

    /// Energy (J) consumed over the window.
    pub fn energy_j(&self, clock: &Clock) -> f64 {
        let seconds = clock.now() as f64 / self.f_clk_hz;
        self.estimate(clock).total_w * seconds
    }

    /// Energy per datapoint (J) — the edge-inference figure of merit the
    /// paper's abstract targets ("energy/performance/accuracy
    /// trade-offs"). `datapoints` = inference + training rows processed
    /// in the window.
    pub fn energy_per_datapoint_j(&self, clock: &Clock, datapoints: u64) -> f64 {
        if datapoints == 0 {
            return f64::NAN;
        }
        self.energy_j(clock) / datapoints as f64
    }

    /// Fabric-only energy per datapoint (J) — excludes the MCU baseline,
    /// which the paper notes dominates total power but idles during TM
    /// operation.
    pub fn fabric_energy_per_datapoint_j(&self, clock: &Clock, datapoints: u64) -> f64 {
        if datapoints == 0 {
            return f64::NAN;
        }
        let seconds = clock.now() as f64 / self.f_clk_hz;
        self.estimate(clock).fabric_w * seconds / datapoints as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Busy run (TM core + management active the whole window) should land
    /// near the paper's 1.725 W.
    #[test]
    fn calibration_matches_paper_total() {
        let mut c = Clock::new();
        c.set_enabled(Module::TmCore, true);
        c.set_enabled(Module::Management, true);
        c.set_enabled(Module::OfflineMemory, true);
        c.set_enabled(Module::AccuracyAnalysis, true);
        c.advance(1_000_000);
        // Typical toggle activity: ~64 TA updates per cycle-pair.
        c.toggle(Module::TmCore, 30_000_000);
        let p = PowerModel::default().estimate(&c);
        assert!(
            (1.60..=1.85).contains(&p.total_w),
            "total {:.3} W should be near the paper's 1.725 W",
            p.total_w
        );
        assert_eq!(p.mcu_w, 1.40, "PS baseline is the paper's 1.4 W");
        assert!(p.fabric_w < 0.45, "fabric stays a small share: {:.3}", p.fabric_w);
    }

    #[test]
    fn clock_gating_saves_power() {
        let mut busy = Clock::new();
        busy.set_enabled(Module::TmCore, true);
        busy.advance(1000);
        let mut gated = Clock::new();
        gated.advance(1000); // fully gated
        let m = PowerModel::default();
        let p_busy = m.estimate(&busy);
        let p_gated = m.estimate(&gated);
        assert!(p_busy.fabric_w > p_gated.fabric_w + 0.1);
        // Gated fabric = static only.
        assert!((p_gated.fabric_w - m.static_w).abs() < 1e-9);
    }

    #[test]
    fn overprovision_gating_visible() {
        // Enabling the over-provisioned slice costs measurable power —
        // the §6 claim that gating unused clauses/TAs reduces overhead.
        let m = PowerModel::default();
        let mut with = Clock::new();
        with.set_enabled(Module::TmCore, true);
        with.set_enabled(Module::TmOverProvision, true);
        with.advance(1000);
        let mut without = Clock::new();
        without.set_enabled(Module::TmCore, true);
        without.advance(1000);
        let d = m.estimate(&with).fabric_w - m.estimate(&without).fabric_w;
        assert!((d - 0.030).abs() < 1e-6, "over-provision slice ≈ 30 mW, got {d}");
    }

    #[test]
    fn toggle_energy_counts() {
        let m = PowerModel::default();
        let mut a = Clock::new();
        a.set_enabled(Module::TmCore, true);
        a.advance(1000);
        let mut b = a.clone();
        b.toggle(Module::TmCore, 1_000_000);
        assert!(m.estimate(&b).fabric_w > m.estimate(&a).fabric_w);
    }

    #[test]
    fn energy_scales_with_time() {
        let m = PowerModel::default();
        let mut c = Clock::new();
        c.set_enabled(Module::TmCore, true);
        c.advance(100_000);
        let e1 = m.energy_j(&c);
        c.advance(100_000);
        let e2 = m.energy_j(&c);
        assert!(e2 > 1.9 * e1 && e2 < 2.1 * e1);
    }

    #[test]
    fn energy_per_datapoint_at_paper_throughput() {
        // At 1 datapoint/clock (§6) and ~1.7 W: ≈ 17 nJ/datapoint total,
        // ≈ 2-3 nJ fabric-only — the edge-scale energy story.
        let m = PowerModel::default();
        let mut c = Clock::new();
        c.set_enabled(Module::TmCore, true);
        c.set_enabled(Module::Management, true);
        let n = 1_000_000u64;
        c.advance(n); // pipelined: one datapoint per cycle
        let e = m.energy_per_datapoint_j(&c, n);
        assert!((1.0e-8..5.0e-8).contains(&e), "total {e:.2e} J/dp");
        let ef = m.fabric_energy_per_datapoint_j(&c, n);
        assert!(ef < e, "fabric-only must exclude the MCU baseline");
        assert!((1.0e-9..1.0e-8).contains(&ef), "fabric {ef:.2e} J/dp");
        assert!(m.energy_per_datapoint_j(&c, 0).is_nan());
    }

    #[test]
    fn frequency_scales_dynamic_only() {
        let mut c = Clock::new();
        c.set_enabled(Module::TmCore, true);
        c.advance(1000);
        let slow = PowerModel { f_clk_hz: 50.0e6, ..Default::default() };
        let fast = PowerModel::default();
        let ps = slow.estimate(&c);
        let pf = fast.estimate(&c);
        assert!(pf.fabric_w > ps.fabric_w);
        assert_eq!(pf.mcu_w, ps.mcu_w);
    }
}
