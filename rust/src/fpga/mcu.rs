//! Microcontroller model (paper §3.8).
//!
//! The on-board MCU (the Zynq PS in the paper's Zybo Z7-20) configures the
//! fabric over AXI, receives accuracy reports through the handshake
//! interface, relays them to a host over UART, and drives run-time
//! reconfiguration (fault injection, filter control, over-provisioning).
//!
//! Here it is a *scripted* device: a schedule of actions keyed by online
//! iteration (exactly how the paper stages its use cases: "faults were
//! injected after 5 online iterations", "a new classification introduced
//! after 5 online iterations"), plus a report log standing in for the UART
//! stream. Every interaction costs cycles: `latency` per handshake and
//! `axi_write_cost` per register write, so experiments expose how MCU
//! speed never throttles the TM beyond handshake stalls (§6).

use crate::fpga::accuracy::AccuracyRecord;
use crate::fpga::rom::SetId;
use crate::tm::fault::FaultMap;

/// Run-time actions the MCU can apply between online iterations.
#[derive(Debug, Clone)]
pub enum McuAction {
    /// Enable/disable the class filter (§3.4.1); `class` selects which.
    SetFilter { enabled: bool, class: usize },
    /// Enable/disable online learning feedback.
    SetOnlineLearning(bool),
    /// Program a whole fault map through the fault controller (§3.1.2) —
    /// costs one AXI write pair per TA.
    InjectFaults(FaultMap),
    /// Force clause outputs (§7 future work: clause-output-level fault
    /// injection): (class, clause, forced value / None clears).
    InjectClauseFaults(Vec<(usize, usize, Option<bool>)>),
    /// Drive the clause-number port (§3.1.1).
    SetActiveClauses(usize),
    /// Expose an over-provisioned class (§3.1.1).
    SetActiveClasses(usize),
    /// Update the specificity port (§3.1).
    SetS(f32),
    /// Update the threshold port.
    SetT(i32),
}

/// A scheduled action: applied just **before** online iteration
/// `at_iteration` begins (iterations are 1-based; 0 = before any online
/// learning).
#[derive(Debug, Clone)]
pub struct ScheduledAction {
    pub at_iteration: usize,
    pub action: McuAction,
}

/// The scripted MCU.
#[derive(Debug, Clone)]
pub struct Mcu {
    /// Cycles the fabric stalls per report handshake (§3.7).
    pub handshake_latency: u64,
    /// Cycles per AXI register write.
    pub axi_write_cost: u64,
    pub schedule: Vec<ScheduledAction>,
    /// Accuracy reports received (the UART stream to the host).
    pub reports: Vec<AccuracyRecord>,
    /// Human-readable UART log lines.
    pub uart_log: Vec<String>,
}

impl Mcu {
    pub fn new(handshake_latency: u64, axi_write_cost: u64) -> Self {
        Mcu {
            handshake_latency,
            axi_write_cost,
            schedule: Vec::new(),
            reports: Vec::new(),
            uart_log: Vec::new(),
        }
    }

    /// Schedule an action before iteration `at_iteration`.
    pub fn schedule(&mut self, at_iteration: usize, action: McuAction) {
        self.schedule.push(ScheduledAction { at_iteration, action });
    }

    /// Take the actions due before `iteration` (in schedule order).
    pub fn due_actions(&self, iteration: usize) -> Vec<McuAction> {
        self.schedule
            .iter()
            .filter(|s| s.at_iteration == iteration)
            .map(|s| s.action.clone())
            .collect()
    }

    /// AXI write cycles an action costs the fabric.
    pub fn action_cost(&self, action: &McuAction) -> u64 {
        match action {
            // addr + data write per TA.
            McuAction::InjectFaults(map) => {
                2 * self.axi_write_cost * map.count().max(1) as u64
            }
            McuAction::InjectClauseFaults(list) => {
                2 * self.axi_write_cost * list.len().max(1) as u64
            }
            _ => self.axi_write_cost,
        }
    }

    /// Receive an offloaded accuracy report (one handshake).
    pub fn receive_report(&mut self, rec: AccuracyRecord) -> u64 {
        let set = match rec.set {
            SetId::OfflineTrain => "offline",
            SetId::Validation => "validation",
            SetId::OnlineTrain => "online",
        };
        self.uart_log.push(format!(
            "iter={} set={} acc={:.2}% ({}/{})",
            rec.iteration,
            set,
            rec.accuracy() * 100.0,
            rec.total - rec.errors,
            rec.total
        ));
        self.reports.push(rec);
        self.handshake_latency
    }

    /// Reports for one set, in iteration order (experiment extraction).
    pub fn curve(&self, set: SetId) -> Vec<(usize, f64)> {
        self.reports
            .iter()
            .filter(|r| r.set == set)
            .map(|r| (r.iteration, r.accuracy()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::fault::Fault;
    use crate::tm::params::TmShape;

    #[test]
    fn schedule_and_due_actions() {
        let mut mcu = Mcu::new(25, 4);
        mcu.schedule(5, McuAction::SetOnlineLearning(false));
        mcu.schedule(5, McuAction::SetFilter { enabled: false, class: 0 });
        mcu.schedule(7, McuAction::SetActiveClauses(16));
        assert_eq!(mcu.due_actions(5).len(), 2);
        assert_eq!(mcu.due_actions(6).len(), 0);
        assert_eq!(mcu.due_actions(7).len(), 1);
        assert!(matches!(
            mcu.due_actions(5)[0],
            McuAction::SetOnlineLearning(false)
        ));
    }

    #[test]
    fn fault_injection_costs_scale_with_map() {
        let mcu = Mcu::new(25, 4);
        let shape = TmShape::iris();
        let map = FaultMap::even_spread(&shape, 0.20, Fault::StuckAt0, 1).unwrap();
        let n = map.count() as u64;
        assert_eq!(mcu.action_cost(&McuAction::InjectFaults(map)), 2 * 4 * n);
        assert_eq!(mcu.action_cost(&McuAction::SetS(1.0)), 4);
    }

    #[test]
    fn reports_logged_and_curves_extracted() {
        let mut mcu = Mcu::new(25, 4);
        for it in 0..3 {
            let stall = mcu.receive_report(AccuracyRecord {
                set: SetId::Validation,
                errors: 10 - it,
                total: 60,
                iteration: it,
                cycles: 63,
            });
            assert_eq!(stall, 25);
        }
        mcu.receive_report(AccuracyRecord {
            set: SetId::OnlineTrain,
            errors: 5,
            total: 60,
            iteration: 0,
            cycles: 63,
        });
        let curve = mcu.curve(SetId::Validation);
        assert_eq!(curve.len(), 3);
        assert!(curve[2].1 > curve[0].1, "improving curve");
        assert_eq!(mcu.uart_log.len(), 4);
        assert!(mcu.uart_log[0].contains("set=validation"));
    }
}
