//! Low-level, per-datapoint state machine (paper §3.2).
//!
//! "…one for high-level system operations and one for low-level, per
//! data-point [operation]. … the low-level manager controls the I/O and
//! operation of the TM itself."
//!
//! Timing model (paper §6): the hardware TM completes inference **and**
//! feedback for all clauses/TAs in **two clock cycles**, plus **one cycle
//! to buffer the I/O**; block-ROM reads take one cycle. Non-pipelined,
//! one datapoint costs `1 (mem) + 1 (I/O) + 2 (compute) = 4` cycles; the
//! pipelined stream sustains **one datapoint per clock** after a fill of
//! [`PIPELINE_FILL`] cycles.

use crate::fpga::clock::{Clock, Module};
use crate::tm::clause::Input;
use crate::tm::engine::train_step_fast_with;
use crate::tm::feedback::StepActivity;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use crate::tm::train_planes::TrainScratch;

/// Cycles to fill the mem→I/O→compute pipeline before the 1-per-clock
/// steady state.
pub const PIPELINE_FILL: u64 = 3;

/// Cycles per datapoint without pipelining.
pub const CYCLES_PER_DATAPOINT: u64 = 4;

/// The two compute cycles of the paper's datapath.
pub const COMPUTE_CYCLES: u64 = 2;

/// What the engine is asked to do with one datapoint.
#[derive(Debug, Clone)]
pub enum Op {
    /// Classify; the result is the predicted class.
    Infer,
    /// Train toward `target` with explicit randomness.
    Train { target: usize, rands: StepRands },
}

/// FSM states, as in the RTL.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LlState {
    Idle,
    /// Data request issued; waiting on memory (ROM latency).
    WaitMemory,
    /// I/O buffering cycle.
    BufferIo,
    /// Clause evaluation (compute cycle 1).
    Evaluate,
    /// Feedback / vote resolution (compute cycle 2).
    Feedback,
}

/// Result of one processed datapoint.
#[derive(Debug, Clone)]
pub struct OpResult {
    pub prediction: usize,
    pub class_sums: Vec<i32>,
    /// Switching activity (zero for pure inference beyond clause evals).
    pub activity: StepActivity,
    pub cycles: u64,
}

/// The per-datapoint engine. Owns no model data — it sequences the TM
/// core (plus a reusable feedback scratch so the per-datapoint step
/// allocates nothing in steady state).
#[derive(Debug, Clone)]
pub struct DatapointEngine {
    state: LlState,
    /// Total datapoints processed (throughput statistics).
    pub processed: u64,
    /// Per-step feedback scratch (sign buffer), reused across ops.
    scratch: TrainScratch,
}

impl Default for DatapointEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl DatapointEngine {
    pub fn new() -> Self {
        DatapointEngine { state: LlState::Idle, processed: 0, scratch: TrainScratch::new() }
    }

    pub fn state(&self) -> LlState {
        self.state
    }

    /// Process one datapoint non-pipelined, walking the FSM state by
    /// state and advancing the clock cycle by cycle (RTL-faithful path).
    ///
    /// `mem_cycles` is the memory latency for this row (ROM = 1).
    pub fn process(
        &mut self,
        tm: &mut MultiTm,
        x: &Input,
        op: &Op,
        params: &TmParams,
        mem_cycles: u64,
        clock: &mut Clock,
    ) -> OpResult {
        debug_assert_eq!(self.state, LlState::Idle);
        let start = clock.now();

        // Request + wait on memory.
        self.state = LlState::WaitMemory;
        clock.with_enabled(Module::OfflineMemory, |c| c.advance(mem_cycles));

        // I/O buffer cycle.
        self.state = LlState::BufferIo;
        clock.with_enabled(Module::Management, |c| c.advance(1));

        // Two compute cycles with the TM core un-gated.
        clock.set_enabled(Module::TmCore, true);
        self.state = LlState::Evaluate;
        clock.advance(1);
        let (class_sums, prediction) = tm.infer(x, params);
        clock.toggle(
            Module::TmCore,
            (params.active_classes * params.active_clauses) as u64,
        );

        self.state = LlState::Feedback;
        clock.advance(1);
        let activity = match op {
            Op::Infer => StepActivity::default(),
            Op::Train { target, rands } => {
                // Word-parallel engine — bit-identical to the scalar
                // oracle given the same StepRands, so the RTL model's
                // numerics (and cycle/toggle accounting) are unchanged.
                let act =
                    train_step_fast_with(tm, x, *target, params, rands, &mut self.scratch);
                clock.toggle(Module::TmCore, act.total_updates() as u64);
                act
            }
        };
        clock.set_enabled(Module::TmCore, false);

        self.state = LlState::Idle;
        self.processed += 1;
        OpResult { prediction, class_sums, activity, cycles: clock.now() - start }
    }

    /// Pipelined cycle cost for a batch of `n` datapoints (§6: throughput
    /// one datapoint per clock; memory reads and I/O buffering overlap
    /// compute).
    pub fn pipelined_cycles(n: usize) -> u64 {
        if n == 0 {
            0
        } else {
            PIPELINE_FILL + n as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TmShape;
    use crate::tm::rng::Xoshiro256;

    fn setup() -> (MultiTm, TmParams, Input) {
        let shape = TmShape::iris();
        let tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        let bits: Vec<bool> = (0..16).map(|k| k % 2 == 0).collect();
        let x = Input::pack(&shape, &bits);
        (tm, p, x)
    }

    #[test]
    fn infer_costs_four_cycles() {
        let (mut tm, p, x) = setup();
        let mut clock = Clock::new();
        let mut eng = DatapointEngine::new();
        let r = eng.process(&mut tm, &x, &Op::Infer, &p, 1, &mut clock);
        assert_eq!(r.cycles, CYCLES_PER_DATAPOINT);
        assert_eq!(clock.now(), 4);
        assert_eq!(eng.processed, 1);
        assert_eq!(eng.state(), LlState::Idle);
        assert_eq!(r.activity, StepActivity::default());
    }

    #[test]
    fn train_same_latency_with_activity() {
        let (mut tm, p, x) = setup();
        let mut clock = Clock::new();
        let mut eng = DatapointEngine::new();
        let mut rng = Xoshiro256::new(5);
        let rands = StepRands::draw(&mut rng, tm.shape());
        let shape = tm.shape().clone();
        let _ = shape;
        let r = eng.process(
            &mut tm,
            &x,
            &Op::Train { target: 0, rands },
            &p,
            1,
            &mut clock,
        );
        assert_eq!(r.cycles, CYCLES_PER_DATAPOINT);
        assert!(r.activity.total_updates() > 0, "feedback moved TAs");
        assert!(clock.activity(Module::TmCore).toggle_events > 0);
    }

    #[test]
    fn tm_core_gated_outside_compute() {
        let (mut tm, p, x) = setup();
        let mut clock = Clock::new();
        let mut eng = DatapointEngine::new();
        eng.process(&mut tm, &x, &Op::Infer, &p, 1, &mut clock);
        // 2 of the 4 cycles had the core un-gated.
        assert_eq!(clock.activity(Module::TmCore).active_cycles, COMPUTE_CYCLES);
        assert_eq!(clock.activity(Module::TmCore).gated_cycles, 2);
        assert!(!clock.is_enabled(Module::TmCore));
    }

    #[test]
    fn slow_memory_stalls_engine() {
        let (mut tm, p, x) = setup();
        let mut clock = Clock::new();
        let mut eng = DatapointEngine::new();
        let r = eng.process(&mut tm, &x, &Op::Infer, &p, 10, &mut clock);
        assert_eq!(r.cycles, 10 + 1 + 2);
    }

    #[test]
    fn pipelined_throughput_one_per_clock() {
        assert_eq!(DatapointEngine::pipelined_cycles(0), 0);
        assert_eq!(DatapointEngine::pipelined_cycles(1), 4);
        assert_eq!(DatapointEngine::pipelined_cycles(60), 63);
        // Steady state: marginal cost of one more datapoint is one cycle.
        let a = DatapointEngine::pipelined_cycles(1000);
        let b = DatapointEngine::pipelined_cycles(1001);
        assert_eq!(b - a, 1);
    }

    #[test]
    fn engine_matches_plain_tm_numerics() {
        // The FSM must not alter numerics: same prediction as tm.infer.
        let (mut tm, p, x) = setup();
        let mut tm2 = tm.clone();
        let mut clock = Clock::new();
        let mut eng = DatapointEngine::new();
        let r = eng.process(&mut tm, &x, &Op::Infer, &p, 1, &mut clock);
        let (sums, pred) = tm2.infer(&x, &p);
        assert_eq!(r.prediction, pred);
        assert_eq!(r.class_sums, sums);
    }
}
