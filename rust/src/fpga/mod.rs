//! Cycle-level model of the paper's FPGA architecture (Fig 2): every RTL
//! subsystem the paper describes, with cycle and switching-activity
//! accounting faithful to §6's timing claims (2-cycle inference+feedback,
//! 1 datapoint/clock pipelined, handshake-only MCU stalls, clock gating).

pub mod accuracy;
pub mod axi;
pub mod clock;
pub mod fault;
pub mod fsm_high;
pub mod fsm_low;
pub mod mcu;
pub mod memmgr;
pub mod online;
pub mod power;
pub mod rom;
pub mod system;

pub use accuracy::{AccuracyAnalyzer, AccuracyRecord, HistoryMode};
pub use axi::{HandshakeStats, Reg, RegisterFile};
pub use clock::{Clock, Module};
pub use fault::FaultController;
pub use fsm_high::{Event, HighLevelManager, Phase};
pub use fsm_low::{DatapointEngine, Op};
pub use mcu::{Mcu, McuAction, ScheduledAction};
pub use memmgr::MemoryManager;
pub use online::OnlineInputPath;
pub use power::{PowerModel, PowerReport};
pub use rom::{BlockRom, Port, RomBank, SetId};
pub use system::{FpgaSystem, RunReport, SystemConfig};
