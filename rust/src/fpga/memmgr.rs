//! Offline memory-management subsystem (paper §3.4.2).
//!
//! "We created a memory management subsystem to retrieve and parse the
//! required offline data from onboard memory and present it to the TM
//! management when required, abstracting the memory interface itself away
//! from the management subsystem."
//!
//! The manager resolves set-relative requests through the [`RomBank`]
//! (cross-validation mapping), applies the class-filter IP on the way out
//! (§3.4.1) and packs rows into TM literals.

use crate::data::filter::ClassFilter;
use crate::fpga::rom::{Port, RomBank, SetId};
use crate::tm::clause::Input;
use crate::tm::params::TmShape;
use anyhow::Result;

/// A fetched row, ready for the TM.
#[derive(Debug, Clone)]
pub struct FetchedRow {
    pub input: Input,
    pub label: usize,
    /// Memory cycles consumed (includes rows scanned past the filter).
    pub cycles: u64,
}

/// The offline memory manager.
#[derive(Debug, Clone)]
pub struct MemoryManager {
    pub shape: TmShape,
    pub filter: ClassFilter,
}

impl MemoryManager {
    pub fn new(shape: &TmShape) -> Self {
        MemoryManager { shape: shape.clone(), filter: ClassFilter::disabled() }
    }

    /// Fetch the row at set-relative index `row` **after filtering**:
    /// filtered rows are scanned past (costing their read cycle, as the
    /// filter IP sits behind the ROM) and do not count toward the index.
    /// Returns `None` when fewer than `row + 1` rows pass the filter.
    pub fn fetch(
        &self,
        bank: &mut RomBank,
        set: SetId,
        row: usize,
        port: Port,
    ) -> Result<Option<FetchedRow>> {
        let mut cycles = 0u64;
        let mut passed = 0usize;
        for raw in 0..bank.set_len(set) {
            let ((bits, label), c) = bank.read(set, raw, port)?;
            cycles += c;
            if self.filter.passes(label) {
                if passed == row {
                    return Ok(Some(FetchedRow {
                        input: Input::pack(&self.shape, &bits),
                        label,
                        cycles,
                    }));
                }
                passed += 1;
            }
        }
        Ok(None)
    }

    /// Number of rows in a set after filtering (one scan).
    pub fn filtered_len(&self, bank: &mut RomBank, set: SetId) -> Result<usize> {
        let mut n = 0;
        for raw in 0..bank.set_len(set) {
            let ((_, label), _) = bank.read(set, raw, Port::A)?;
            if self.filter.passes(label) {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Stream a whole (filtered) set in order — the pipelined bulk path
    /// used by training epochs and accuracy analysis. One ROM read per
    /// stored row; filtered rows are dropped after the read, exactly like
    /// the RTL filter IP. Returns (rows, total memory cycles).
    pub fn stream(
        &self,
        bank: &mut RomBank,
        set: SetId,
        port: Port,
        limit: Option<usize>,
    ) -> Result<(Vec<(Input, usize)>, u64)> {
        let mut rows = Vec::new();
        let mut cycles = 0u64;
        for raw in 0..bank.set_len(set) {
            if let Some(l) = limit {
                if rows.len() == l {
                    break;
                }
            }
            let ((bits, label), c) = bank.read(set, raw, port)?;
            cycles += c;
            if self.filter.passes(label) {
                rows.push((Input::pack(&self.shape, &bits), label));
            }
        }
        Ok((rows, cycles))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockPlan;
    use crate::data::dataset::BoolDataset;
    use crate::data::iris;

    fn bank() -> RomBank {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 1).unwrap();
        let blocks: Vec<BoolDataset> = (0..5).map(|i| plan.block(i).clone()).collect();
        RomBank::new(&blocks, &[0, 1, 2, 3, 4], (1, 2, 2)).unwrap()
    }

    #[test]
    fn fetch_unfiltered_costs_scan() {
        let mm = MemoryManager::new(&TmShape::iris());
        let mut b = bank();
        let r = mm.fetch(&mut b, SetId::OfflineTrain, 0, Port::A).unwrap().unwrap();
        assert_eq!(r.cycles, 1);
        let r = mm.fetch(&mut b, SetId::OfflineTrain, 5, Port::A).unwrap().unwrap();
        assert_eq!(r.cycles, 6, "scan reads 6 rows to reach index 5");
        assert!(r.label < 3);
    }

    #[test]
    fn fetch_past_end_is_none() {
        let mm = MemoryManager::new(&TmShape::iris());
        let mut b = bank();
        assert!(mm.fetch(&mut b, SetId::OfflineTrain, 30, Port::A).unwrap().is_none());
    }

    #[test]
    fn filter_reduces_visible_set() {
        let mut mm = MemoryManager::new(&TmShape::iris());
        mm.filter = ClassFilter::removing(0);
        let mut b = bank();
        assert_eq!(mm.filtered_len(&mut b, SetId::OfflineTrain).unwrap(), 20);
        assert_eq!(mm.filtered_len(&mut b, SetId::Validation).unwrap(), 40);
        // Every fetched row passes the filter.
        for i in 0..20 {
            let r = mm.fetch(&mut b, SetId::OfflineTrain, i, Port::A).unwrap().unwrap();
            assert_ne!(r.label, 0);
        }
        assert!(mm.fetch(&mut b, SetId::OfflineTrain, 20, Port::A).unwrap().is_none());
    }

    #[test]
    fn stream_matches_fetch_sequence() {
        let mut mm = MemoryManager::new(&TmShape::iris());
        mm.filter = ClassFilter::removing(2);
        let mut b1 = bank();
        let mut b2 = bank();
        let (rows, cycles) = mm.stream(&mut b1, SetId::OfflineTrain, Port::A, None).unwrap();
        assert_eq!(rows.len(), 20);
        assert_eq!(cycles, 30, "one read per stored row");
        for (i, (input, label)) in rows.iter().enumerate() {
            let f = mm.fetch(&mut b2, SetId::OfflineTrain, i, Port::A).unwrap().unwrap();
            assert_eq!(f.label, *label);
            assert_eq!(&f.input, input);
        }
    }

    #[test]
    fn stream_limit_truncates() {
        let mm = MemoryManager::new(&TmShape::iris());
        let mut b = bank();
        let (rows, cycles) =
            mm.stream(&mut b, SetId::OfflineTrain, Port::A, Some(20)).unwrap();
        assert_eq!(rows.len(), 20, "paper §5.1: offline training uses 20 of 30");
        assert_eq!(cycles, 20);
    }
}
