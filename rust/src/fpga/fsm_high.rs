//! High-level system state machine (paper §3.2 / Fig 3).
//!
//! Execution flow: after initial offline training, accuracy is analysed
//! on the offline-training set and optionally the validation and
//! online-training sets; online learning then runs for a set number of
//! datapoints before accuracy analysis is re-run, looping for a
//! configured number of online iterations.

use anyhow::{bail, Result};

/// The Fig-3 phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Reset / waiting for the start bit.
    Idle,
    /// Initial offline training: `epoch` in `0..offline_epochs`.
    OfflineTraining { epoch: usize },
    /// Accuracy analysis after offline training or after online
    /// iteration `iteration` (0 = post-offline).
    Analysis { iteration: usize },
    /// Online learning pass `iteration` (1-based).
    OnlineLearning { iteration: usize },
    /// All iterations done.
    Halted,
}

/// Completion events the subsystems raise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    Start,
    EpochDone,
    AnalysisDone,
    OnlinePassDone,
}

/// The high-level manager: owns the phase sequencing and nothing else.
#[derive(Debug, Clone)]
pub struct HighLevelManager {
    pub offline_epochs: usize,
    pub online_iterations: usize,
    phase: Phase,
    /// Transition trace (diagnostics / FSM-coverage tests).
    pub trace: Vec<Phase>,
}

impl HighLevelManager {
    pub fn new(offline_epochs: usize, online_iterations: usize) -> Self {
        HighLevelManager {
            offline_epochs,
            online_iterations,
            phase: Phase::Idle,
            trace: vec![Phase::Idle],
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    fn goto(&mut self, p: Phase) -> Phase {
        self.phase = p;
        self.trace.push(p);
        p
    }

    /// Drive one transition with a completion event; returns the next
    /// phase. Rejects events that are illegal in the current phase (an
    /// RTL assertion).
    pub fn advance(&mut self, ev: Event) -> Result<Phase> {
        let next = match (self.phase, ev) {
            (Phase::Idle, Event::Start) => {
                if self.offline_epochs == 0 {
                    Phase::Analysis { iteration: 0 }
                } else {
                    Phase::OfflineTraining { epoch: 0 }
                }
            }
            (Phase::OfflineTraining { epoch }, Event::EpochDone) => {
                if epoch + 1 < self.offline_epochs {
                    Phase::OfflineTraining { epoch: epoch + 1 }
                } else {
                    Phase::Analysis { iteration: 0 }
                }
            }
            (Phase::Analysis { iteration }, Event::AnalysisDone) => {
                if iteration < self.online_iterations {
                    Phase::OnlineLearning { iteration: iteration + 1 }
                } else {
                    Phase::Halted
                }
            }
            (Phase::OnlineLearning { iteration }, Event::OnlinePassDone) => {
                Phase::Analysis { iteration }
            }
            (phase, ev) => bail!("illegal event {ev:?} in phase {phase:?}"),
        };
        Ok(self.goto(next))
    }

    pub fn is_halted(&self) -> bool {
        self.phase == Phase::Halted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_flow_sequence() {
        // 2 offline epochs, 3 online iterations (miniature Fig 3).
        let mut hl = HighLevelManager::new(2, 3);
        assert_eq!(hl.phase(), Phase::Idle);
        assert_eq!(hl.advance(Event::Start).unwrap(), Phase::OfflineTraining { epoch: 0 });
        assert_eq!(
            hl.advance(Event::EpochDone).unwrap(),
            Phase::OfflineTraining { epoch: 1 }
        );
        assert_eq!(hl.advance(Event::EpochDone).unwrap(), Phase::Analysis { iteration: 0 });
        for it in 1..=3 {
            assert_eq!(
                hl.advance(Event::AnalysisDone).unwrap(),
                Phase::OnlineLearning { iteration: it }
            );
            assert_eq!(
                hl.advance(Event::OnlinePassDone).unwrap(),
                Phase::Analysis { iteration: it }
            );
        }
        assert_eq!(hl.advance(Event::AnalysisDone).unwrap(), Phase::Halted);
        assert!(hl.is_halted());
        // Trace covers: idle + 2 offline + (3+1) analysis + 3 online + halt.
        assert_eq!(hl.trace.len(), 1 + 2 + 4 + 3 + 1);
    }

    #[test]
    fn zero_epochs_skips_offline() {
        let mut hl = HighLevelManager::new(0, 1);
        assert_eq!(hl.advance(Event::Start).unwrap(), Phase::Analysis { iteration: 0 });
    }

    #[test]
    fn zero_iterations_halts_after_first_analysis() {
        let mut hl = HighLevelManager::new(1, 0);
        hl.advance(Event::Start).unwrap();
        hl.advance(Event::EpochDone).unwrap();
        assert_eq!(hl.advance(Event::AnalysisDone).unwrap(), Phase::Halted);
    }

    #[test]
    fn illegal_events_rejected() {
        let mut hl = HighLevelManager::new(1, 1);
        assert!(hl.advance(Event::EpochDone).is_err(), "no epoch during idle");
        hl.advance(Event::Start).unwrap();
        assert!(hl.advance(Event::AnalysisDone).is_err());
        assert!(hl.advance(Event::OnlinePassDone).is_err());
    }
}
