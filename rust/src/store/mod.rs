//! Durable serving state: a write-ahead log + on-disk checkpoint store
//! that make a `tmfpga serve` process crash-consistent.
//!
//! The paper's premise — training interleaved with inference *in the
//! field* — is only credible if a power cut doesn't erase everything
//! learned since deployment (the FPGA analogue: persisting TA state
//! off-chip across reconfiguration). This module is that persistence:
//!
//! - [`wal`]: one hub-wide segmented write-ahead log. Every model
//!   creation (with its genesis snapshot embedded) and every sequenced
//!   update is appended *before* it is applied in memory, under a
//!   configurable [`SyncPolicy`]. Torn tails are truncated on open;
//!   interior damage is a typed error (see `wal.rs` for why those are
//!   cleanly distinguishable).
//! - [`ckpt`]: durable TMFS v2 checkpoints, published atomically
//!   (temp → fsync → rename), plus the CRC-tailed `MANIFEST` mapping
//!   model id → (name, base_seed, newest checkpoint seq).
//! - [`Store`]: the composition. `open` rebuilds the full multi-tenant
//!   picture — manifest ∪ checkpoint files ∪ WAL — repairing what a
//!   crash window can legally leave behind (stale manifest, missing
//!   genesis checkpoint, torn tail) with exact counter accounting, and
//!   failing **typed** on anything real damage can produce. Replay of
//!   the returned per-model log suffix through the keyed
//!   `(base_seed, seq)` update path is bit-identical to a process that
//!   never crashed.
//!
//! All disk access goes through the [`Disk`] trait so the chaos
//! harness can wrap a [`FaultDisk`] around the real filesystem and
//! inject a crash, `ENOSPC`, or a short write at any chosen write
//! boundary. After any failed write the store is **poisoned**: every
//! later operation fails typed rather than risking a log whose
//! physical tail no longer matches the writer's bookkeeping
//! (fail-stop, the same stance the shard supervisor takes).
//!
//! Durability model: the crash soak kills the *process*, which on any
//! OS keeps completed `write`s in the page cache, so replay after a
//! kill sees every appended byte regardless of sync policy. The sync
//! policy governs the stronger power-loss story: `Always` bounds loss
//! to the in-flight record, `EveryN(n)` to the last `n`, `OnDemand` to
//! the last explicit flush (the front end flushes on drain).

pub mod ckpt;
pub mod wal;

pub use ckpt::{ManifestEntry, MANIFEST_NAME};
pub use wal::{Wal, WalOp, WalRecord, WalStats};

use crate::serve::checkpoint;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Typed storage failures. Every disk-fault kind the chaos harness can
/// inject (and every kind real damage can produce) surfaces as one of
/// these — never a silent wrong answer, never a panic.
#[derive(Debug)]
pub enum StoreError {
    Io { op: &'static str, path: PathBuf, source: std::io::Error },
    /// Out of disk space (real `ENOSPC` or injected).
    NoSpace { path: PathBuf },
    /// A write persisted fewer bytes than requested (injected; real
    /// short writes surface as `Io` from `write_all`).
    ShortWrite { path: PathBuf, wrote: usize, want: usize },
    /// Injected process death at a write boundary ([`FaultDisk`]).
    Crashed { op_index: u64 },
    /// A previous write failed; the store refuses further operations.
    Poisoned,
    /// A complete WAL frame whose CRC or payload decoding fails: bit
    /// corruption inside the log (a torn tail is repaired, not this).
    CorruptRecord { segment: PathBuf, offset: u64, detail: String },
    /// The WAL segment chain has a gap: a segment named for this
    /// position should exist and doesn't (or is empty mid-chain).
    MissingSegment { expected_pos: u64, found: PathBuf },
    CorruptManifest { detail: String },
    /// A checkpoint that should be loadable isn't, with no fallback.
    CorruptCheckpoint { path: PathBuf, detail: String },
    /// No durable checkpoint (nor WAL genesis) can rebuild this model.
    NoUsableCheckpoint { model_id: u64 },
    /// The WAL's update suffix doesn't join up with the checkpoint:
    /// replay needs seq `have + 1`, the log resumes at `found`.
    SeqGap { model_id: u64, have: u64, found: u64 },
    UnknownModel { model_id: u64 },
    DuplicateModel { model_id: u64 },
    BadName { name: String },
    BadConfig { detail: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io { op, path, source } => {
                write!(f, "store: {op} {}: {source}", path.display())
            }
            StoreError::NoSpace { path } => {
                write!(f, "store: no space writing {}", path.display())
            }
            StoreError::ShortWrite { path, wrote, want } => {
                write!(f, "store: short write to {} ({wrote}/{want} bytes)", path.display())
            }
            StoreError::Crashed { op_index } => {
                write!(f, "store: injected crash at write boundary {op_index}")
            }
            StoreError::Poisoned => {
                write!(f, "store: poisoned by an earlier write failure")
            }
            StoreError::CorruptRecord { segment, offset, detail } => {
                write!(
                    f,
                    "store: corrupt WAL record in {} at offset {offset}: {detail}",
                    segment.display()
                )
            }
            StoreError::MissingSegment { expected_pos, found } => {
                write!(
                    f,
                    "store: WAL gap: expected segment starting at position {expected_pos}, \
                     found {}",
                    found.display()
                )
            }
            StoreError::CorruptManifest { detail } => {
                write!(f, "store: corrupt manifest: {detail}")
            }
            StoreError::CorruptCheckpoint { path, detail } => {
                write!(f, "store: corrupt checkpoint {}: {detail}", path.display())
            }
            StoreError::NoUsableCheckpoint { model_id } => {
                write!(f, "store: model {model_id}: no usable checkpoint or WAL genesis")
            }
            StoreError::SeqGap { model_id, have, found } => {
                write!(
                    f,
                    "store: model {model_id}: WAL gap after seq {have} (log resumes at {found})"
                )
            }
            StoreError::UnknownModel { model_id } => {
                write!(f, "store: unknown model id {model_id}")
            }
            StoreError::DuplicateModel { model_id } => {
                write!(f, "store: duplicate model id {model_id}")
            }
            StoreError::BadName { name } => write!(f, "store: invalid model name {name:?}"),
            StoreError::BadConfig { detail } => write!(f, "store: bad config: {detail}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// When WAL appends reach stable storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// fsync after every append: power loss loses at most the
    /// in-flight record.
    Always,
    /// fsync every `n` appends (and on rotation/drain).
    EveryN(u64),
    /// fsync only on explicit [`Store::sync`] (drain, shutdown).
    OnDemand,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreConfig {
    /// Rotate the WAL to a fresh segment once the tail reaches this
    /// size (records never span segments; a segment may exceed this by
    /// one record).
    pub segment_bytes: u64,
    pub sync_policy: SyncPolicy,
    /// Durable checkpoints retained per model (newest-first), ≥ 1.
    pub retained_ckpts: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            segment_bytes: 64 * 1024,
            sync_policy: SyncPolicy::Always,
            retained_ckpts: 2,
        }
    }
}

impl StoreConfig {
    pub fn validate(&self) -> Result<(), StoreError> {
        if self.segment_bytes == 0 {
            return Err(StoreError::BadConfig { detail: "segment_bytes must be ≥ 1".into() });
        }
        if self.retained_ckpts == 0 {
            return Err(StoreError::BadConfig { detail: "retained_ckpts must be ≥ 1".into() });
        }
        if let SyncPolicy::EveryN(0) = self.sync_policy {
            return Err(StoreError::BadConfig { detail: "EveryN sync period must be ≥ 1".into() });
        }
        Ok(())
    }
}

/// Filesystem access boundary. Everything the store does to disk goes
/// through one of these, so [`FaultDisk`] can interpose faults at
/// exactly the write boundaries the crash matrix enumerates.
pub trait Disk: Send {
    fn create_dir_all(&mut self, path: &Path) -> Result<(), StoreError>;
    /// All entries of `dir`, sorted, files only.
    fn list(&mut self, dir: &Path) -> Result<Vec<PathBuf>, StoreError>;
    fn read(&mut self, path: &Path) -> Result<Vec<u8>, StoreError>;
    /// Append bytes to `path`, creating it if absent.
    fn append(&mut self, path: &Path, bytes: &[u8]) -> Result<(), StoreError>;
    /// Publish `bytes` at `path` atomically: temp sibling → fsync →
    /// rename → directory fsync. Readers see the old file or the new
    /// file, never a prefix.
    fn write_atomic(&mut self, path: &Path, bytes: &[u8]) -> Result<(), StoreError>;
    fn truncate(&mut self, path: &Path, len: u64) -> Result<(), StoreError>;
    fn remove(&mut self, path: &Path) -> Result<(), StoreError>;
    fn sync(&mut self, path: &Path) -> Result<(), StoreError>;
    fn exists(&mut self, path: &Path) -> Result<bool, StoreError>;
}

fn io_err(op: &'static str, path: &Path, e: std::io::Error) -> StoreError {
    if e.kind() == std::io::ErrorKind::StorageFull {
        StoreError::NoSpace { path: path.to_path_buf() }
    } else {
        StoreError::Io { op, path: path.to_path_buf(), source: e }
    }
}

/// The real filesystem.
#[derive(Debug, Default)]
pub struct RealDisk;

impl Disk for RealDisk {
    fn create_dir_all(&mut self, path: &Path) -> Result<(), StoreError> {
        std::fs::create_dir_all(path).map_err(|e| io_err("create_dir_all", path, e))
    }

    fn list(&mut self, dir: &Path) -> Result<Vec<PathBuf>, StoreError> {
        let rd = std::fs::read_dir(dir).map_err(|e| io_err("read_dir", dir, e))?;
        let mut out = Vec::new();
        for entry in rd {
            let entry = entry.map_err(|e| io_err("read_dir", dir, e))?;
            let ft = entry.file_type().map_err(|e| io_err("file_type", dir, e))?;
            if ft.is_file() {
                out.push(entry.path());
            }
        }
        out.sort();
        Ok(out)
    }

    fn read(&mut self, path: &Path) -> Result<Vec<u8>, StoreError> {
        std::fs::read(path).map_err(|e| io_err("read", path, e))
    }

    fn append(&mut self, path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(path)
            .map_err(|e| io_err("open append", path, e))?;
        f.write_all(bytes).map_err(|e| io_err("append", path, e))
    }

    fn write_atomic(&mut self, path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        let mut f =
            std::fs::File::create(&tmp).map_err(|e| io_err("create temp", &tmp, e))?;
        f.write_all(bytes).map_err(|e| io_err("write temp", &tmp, e))?;
        f.sync_all().map_err(|e| io_err("sync temp", &tmp, e))?;
        drop(f);
        std::fs::rename(&tmp, path).map_err(|e| io_err("rename", path, e))?;
        // Make the rename itself durable.
        if let Some(dir) = path.parent() {
            std::fs::File::open(dir)
                .and_then(|d| d.sync_all())
                .map_err(|e| io_err("sync dir", dir, e))?;
        }
        Ok(())
    }

    fn truncate(&mut self, path: &Path, len: u64) -> Result<(), StoreError> {
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .map_err(|e| io_err("open truncate", path, e))?;
        f.set_len(len).map_err(|e| io_err("truncate", path, e))?;
        f.sync_all().map_err(|e| io_err("sync truncate", path, e))
    }

    fn remove(&mut self, path: &Path) -> Result<(), StoreError> {
        std::fs::remove_file(path).map_err(|e| io_err("remove", path, e))
    }

    fn sync(&mut self, path: &Path) -> Result<(), StoreError> {
        std::fs::File::open(path)
            .and_then(|f| f.sync_all())
            .map_err(|e| io_err("sync", path, e))
    }

    fn exists(&mut self, path: &Path) -> Result<bool, StoreError> {
        Ok(path.exists())
    }
}

/// What an injected fault does at its write boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Process death: an append persists a *prefix* of the frame (the
    /// torn tail the WAL must repair), an atomic publish persists
    /// nothing, and every subsequent operation keeps failing.
    Crash,
    /// `ENOSPC`: nothing is persisted; the one operation fails typed.
    Enospc,
    /// A partial append that *returns an error* (the caller knows);
    /// the on-disk tail is torn exactly as in a crash.
    ShortWrite,
}

/// Fire `kind` at the `fail_at_op`-th write boundary (1-based; write
/// boundaries are WAL appends and atomic publishes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub fail_at_op: u64,
    pub kind: FaultKind,
}

/// [`Disk`] wrapper injecting storage faults at exact write
/// boundaries; the shared counter lets a driver first measure how many
/// boundaries a clean run crosses, then sweep `fail_at_op` over all of
/// them.
pub struct FaultDisk {
    inner: RealDisk,
    plan: Option<FaultPlan>,
    ops: Arc<AtomicU64>,
    crashed: bool,
}

impl FaultDisk {
    pub fn new(plan: Option<FaultPlan>) -> Self {
        FaultDisk { inner: RealDisk, plan, ops: Arc::new(AtomicU64::new(0)), crashed: false }
    }

    /// Live count of write boundaries crossed (appends + publishes).
    pub fn op_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.ops)
    }

    /// Returns the fault to fire for this write boundary, if any.
    fn arm(&mut self) -> Option<FaultKind> {
        let op = self.ops.fetch_add(1, Ordering::Relaxed) + 1;
        if self.crashed {
            return Some(FaultKind::Crash);
        }
        match self.plan {
            Some(p) if p.fail_at_op == op => {
                if p.kind == FaultKind::Crash {
                    self.crashed = true;
                }
                Some(p.kind)
            }
            _ => None,
        }
    }

    fn op_index(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }
}

impl Disk for FaultDisk {
    fn create_dir_all(&mut self, path: &Path) -> Result<(), StoreError> {
        self.inner.create_dir_all(path)
    }

    fn list(&mut self, dir: &Path) -> Result<Vec<PathBuf>, StoreError> {
        self.inner.list(dir)
    }

    fn read(&mut self, path: &Path) -> Result<Vec<u8>, StoreError> {
        self.inner.read(path)
    }

    fn append(&mut self, path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        match self.arm() {
            None => self.inner.append(path, bytes),
            Some(FaultKind::Crash) => {
                // Dying mid-write leaves a prefix on disk: the torn tail.
                self.inner.append(path, &bytes[..bytes.len() / 2])?;
                Err(StoreError::Crashed { op_index: self.op_index() })
            }
            Some(FaultKind::Enospc) => Err(StoreError::NoSpace { path: path.to_path_buf() }),
            Some(FaultKind::ShortWrite) => {
                let wrote = bytes.len() / 2;
                self.inner.append(path, &bytes[..wrote])?;
                Err(StoreError::ShortWrite { path: path.to_path_buf(), wrote, want: bytes.len() })
            }
        }
    }

    fn write_atomic(&mut self, path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        match self.arm() {
            None => self.inner.write_atomic(path, bytes),
            // Atomic publication means a fault before the rename
            // publishes nothing, whatever the kind.
            Some(FaultKind::Crash) => Err(StoreError::Crashed { op_index: self.op_index() }),
            Some(FaultKind::Enospc) => Err(StoreError::NoSpace { path: path.to_path_buf() }),
            Some(FaultKind::ShortWrite) => {
                Err(StoreError::ShortWrite { path: path.to_path_buf(), wrote: 0, want: bytes.len() })
            }
        }
    }

    fn truncate(&mut self, path: &Path, len: u64) -> Result<(), StoreError> {
        if self.crashed {
            return Err(StoreError::Crashed { op_index: self.op_index() });
        }
        self.inner.truncate(path, len)
    }

    fn remove(&mut self, path: &Path) -> Result<(), StoreError> {
        if self.crashed {
            return Err(StoreError::Crashed { op_index: self.op_index() });
        }
        self.inner.remove(path)
    }

    fn sync(&mut self, path: &Path) -> Result<(), StoreError> {
        if self.crashed {
            return Err(StoreError::Crashed { op_index: self.op_index() });
        }
        self.inner.sync(path)
    }

    fn exists(&mut self, path: &Path) -> Result<bool, StoreError> {
        self.inner.exists(path)
    }
}

/// Exact accounting of everything `Store::open` observed and repaired.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryReport {
    pub wal_segments_scanned: u64,
    pub torn_tails_truncated: u64,
    pub wal_records_replayed: u64,
    /// Checkpoint files skipped because they failed verification (or
    /// couldn't be read); an older file or the WAL genesis stood in.
    pub corrupt_checkpoints_rejected: u64,
    /// Manifest rows that disagreed with the recovered truth (missing
    /// model, wrong newest-checkpoint seq) — repaired and rewritten.
    pub stale_manifest_entries: u64,
    /// Whole manifests rejected (corrupt/unreadable) and rebuilt from
    /// checkpoint files + WAL.
    pub manifests_rejected: u64,
    pub orphan_temps_removed: u64,
    pub models_recovered: u64,
}

/// Lifetime write counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    pub wal: WalStats,
    pub ckpts_published: u64,
    pub ckpts_retired: u64,
}

/// One model as rebuilt from disk: its newest durable snapshot plus
/// the WAL suffix (`seq > ckpt_seq`, contiguous) to replay on top.
#[derive(Debug, Clone)]
pub struct RecoveredModel {
    pub id: u64,
    pub name: String,
    pub base_seed: u64,
    pub ckpt_seq: u64,
    /// TMFS v2 bytes (already `quick_check`ed; the hub still runs the
    /// full paranoid restore before trusting them).
    pub ckpt_bytes: Vec<u8>,
    pub ops: Vec<(u64, WalOp)>,
}

fn valid_model_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.'))
}

/// The durable store: WAL + checkpoints + manifest behind one façade.
pub struct Store {
    disk: Box<dyn Disk>,
    root: PathBuf,
    ckpt_dir: PathBuf,
    cfg: StoreConfig,
    wal: Wal,
    manifest: BTreeMap<u64, ManifestEntry>,
    /// Per model: checkpoint files on disk, `(seq, path)` ascending.
    ckpt_files: BTreeMap<u64, Vec<(u64, PathBuf)>>,
    /// Per model: oldest WAL position still needed for replay.
    floors: BTreeMap<u64, u64>,
    report: RecoveryReport,
    stats: StoreStats,
    poisoned: bool,
}

impl Store {
    /// Open (or initialise) the store at `root`, rebuilding every
    /// model recorded on disk. See the module docs for the recovery
    /// semantics; the returned models' checkpoints have passed framing
    /// verification and their log suffixes are contiguous.
    pub fn open(
        mut disk: Box<dyn Disk>,
        root: &Path,
        cfg: StoreConfig,
    ) -> Result<(Store, Vec<RecoveredModel>), StoreError> {
        cfg.validate()?;
        let ckpt_dir = root.join("ckpt");
        let wal_dir = root.join("wal");
        disk.create_dir_all(root)?;
        disk.create_dir_all(&ckpt_dir)?;
        let mut report = RecoveryReport::default();

        // Sweep orphan temp files from interrupted atomic publishes.
        for dir in [root, &ckpt_dir] {
            for path in disk.list(dir)? {
                if path.extension().is_some_and(|e| e == "tmp") {
                    disk.remove(&path)?;
                    report.orphan_temps_removed += 1;
                }
            }
        }

        // The manifest is advisory: a corrupt one is rejected (counted)
        // and rebuilt below, as long as checkpoints + WAL carry enough.
        let manifest_on_disk = match ckpt::load_manifest(disk.as_mut(), root) {
            Ok(m) => m,
            Err(StoreError::CorruptManifest { .. }) => {
                report.manifests_rejected += 1;
                None
            }
            Err(e) => return Err(e),
        };
        let mut manifest = manifest_on_disk.clone().unwrap_or_default();

        let mut ckpt_files = ckpt::scan(disk.as_mut(), &ckpt_dir)?;
        let (mut wal, wal_records, wal_rep) =
            Wal::open(disk.as_mut(), &wal_dir, cfg.segment_bytes, cfg.sync_policy)?;
        report.wal_segments_scanned = wal_rep.segments_scanned;
        report.torn_tails_truncated = wal_rep.torn_tails_truncated;

        // Index the log: creations (identity + genesis) and updates.
        let mut creates: BTreeMap<u64, (u64, String, Vec<u8>)> = BTreeMap::new();
        let mut updates: BTreeMap<u64, Vec<(u64, u64, WalOp)>> = BTreeMap::new();
        for (pos, rec) in wal_records {
            match rec {
                WalRecord::Create { model_id, base_seed, name, genesis } => {
                    if creates.insert(model_id, (base_seed, name, genesis)).is_some() {
                        return Err(StoreError::DuplicateModel { model_id });
                    }
                }
                WalRecord::Update { model_id, seq, op } => {
                    updates.entry(model_id).or_default().push((pos, seq, op));
                }
            }
        }

        let mut ids: Vec<u64> = manifest.keys().copied().collect();
        ids.extend(creates.keys().copied());
        ids.extend(ckpt_files.keys().copied());
        ids.sort_unstable();
        ids.dedup();

        let mut recovered = Vec::new();
        let mut floors = BTreeMap::new();
        let mut stats = StoreStats::default();
        for id in ids {
            // Identity: manifest row, else the WAL Create record. Both
            // present must agree — a mismatch means cross-wired files.
            let created = creates.get(&id);
            let (name, base_seed) = match (manifest.get(&id), created) {
                (Some(e), Some((seed, name, _))) => {
                    if e.name != *name || e.base_seed != *seed {
                        return Err(StoreError::CorruptManifest {
                            detail: format!(
                                "model {id}: manifest identity ({}, {}) disagrees with \
                                 WAL Create ({name}, {seed})",
                                e.name, e.base_seed
                            ),
                        });
                    }
                    (name.clone(), *seed)
                }
                (Some(e), None) => (e.name.clone(), e.base_seed),
                (None, Some((seed, name, _))) => (name.clone(), *seed),
                (None, None) => return Err(StoreError::UnknownModel { model_id: id }),
            };

            // Newest checkpoint file that verifies; older ones stand in
            // for damaged newer ones (counted).
            let mut chosen: Option<(u64, Vec<u8>)> = None;
            for (seq, path) in ckpt_files.get(&id).map(|v| v.as_slice()).unwrap_or(&[]).iter().rev()
            {
                match disk.read(path) {
                    Ok(bytes) if checkpoint::quick_check(&bytes) == Some(*seq) => {
                        chosen = Some((*seq, bytes));
                        break;
                    }
                    _ => report.corrupt_checkpoints_rejected += 1,
                }
            }
            // Last resort: the genesis snapshot embedded in the WAL.
            let mut publish_genesis = false;
            let (ckpt_seq, ckpt_bytes) = match chosen {
                Some(c) => c,
                None => match created {
                    Some((_, _, genesis)) => match checkpoint::quick_check(genesis) {
                        Some(gseq) => {
                            publish_genesis = true;
                            (gseq, genesis.clone())
                        }
                        None => return Err(StoreError::NoUsableCheckpoint { model_id: id }),
                    },
                    None => return Err(StoreError::NoUsableCheckpoint { model_id: id }),
                },
            };

            // Manifest row must name this exact checkpoint; anything
            // else is the publication/rewrite crash window (or damage)
            // — counted, repaired below.
            match manifest.get(&id) {
                Some(e) if e.ckpt_seq == ckpt_seq => {}
                _ => report.stale_manifest_entries += 1,
            }
            manifest.insert(
                id,
                ManifestEntry { name: name.clone(), base_seed, ckpt_seq },
            );

            // Replayable suffix: contiguous seqs strictly above the
            // checkpoint. Earlier records are the normal overlap;
            // a hole means retention outran a (damaged) checkpoint.
            let mut ops = Vec::new();
            let mut floor_pos = None;
            let mut have = ckpt_seq;
            for (pos, seq, op) in updates.remove(&id).unwrap_or_default() {
                if seq <= ckpt_seq {
                    continue;
                }
                if seq != have + 1 {
                    return Err(StoreError::SeqGap { model_id: id, have, found: seq });
                }
                have = seq;
                floor_pos.get_or_insert(pos);
                ops.push((seq, op));
            }
            report.wal_records_replayed += ops.len() as u64;
            report.models_recovered += 1;

            if publish_genesis {
                // Crash window between WAL Create and checkpoint
                // publication: finish the job so the Create record can
                // be retired.
                let path = ckpt_dir.join(ckpt::ckpt_file_name(id, ckpt_seq));
                disk.write_atomic(&path, &ckpt_bytes)?;
                let files = ckpt_files.entry(id).or_default();
                files.push((ckpt_seq, path));
                files.sort_by_key(|&(s, _)| s);
                stats.ckpts_published += 1;
            }

            floors.insert(id, floor_pos.unwrap_or(wal.next_pos()));
            recovered.push(RecoveredModel {
                id,
                name,
                base_seed,
                ckpt_seq,
                ckpt_bytes,
                ops,
            });
        }

        // Updates for a model with no identity anywhere: real damage.
        if let Some((&id, _)) = updates.iter().next() {
            return Err(StoreError::UnknownModel { model_id: id });
        }

        // Repair the manifest durably before any retention could erase
        // the WAL records that made the repair possible.
        if manifest_on_disk.as_ref() != Some(&manifest) {
            ckpt::write_manifest(disk.as_mut(), root, &manifest)?;
        }

        let mut store = Store {
            disk,
            root: root.to_path_buf(),
            ckpt_dir,
            cfg,
            wal,
            manifest,
            ckpt_files,
            floors,
            report,
            stats,
            poisoned: false,
        };
        store.run_retention()?;
        Ok((store, recovered))
    }

    pub fn report(&self) -> &RecoveryReport {
        &self.report
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats { wal: self.wal.stats(), ..self.stats }
    }

    pub fn manifest(&self) -> &BTreeMap<u64, ManifestEntry> {
        &self.manifest
    }

    pub fn wal_next_pos(&self) -> u64 {
        self.wal.next_pos()
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn guard(&self) -> Result<(), StoreError> {
        if self.poisoned {
            return Err(StoreError::Poisoned);
        }
        Ok(())
    }

    fn poison_on_err<T>(&mut self, r: Result<T, StoreError>) -> Result<T, StoreError> {
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    /// Record a model's birth: the Create record (carrying the genesis
    /// snapshot) is appended to the WAL first — the durable source of
    /// truth — then the genesis checkpoint and manifest row are
    /// published. A crash between those steps is exactly the window
    /// `open` repairs.
    pub fn log_create(
        &mut self,
        model_id: u64,
        name: &str,
        base_seed: u64,
        genesis: &[u8],
    ) -> Result<(), StoreError> {
        self.guard()?;
        if !valid_model_name(name) {
            return Err(StoreError::BadName { name: name.to_string() });
        }
        if self.manifest.contains_key(&model_id) {
            return Err(StoreError::DuplicateModel { model_id });
        }
        let Some(genesis_seq) = checkpoint::quick_check(genesis) else {
            return Err(StoreError::CorruptCheckpoint {
                path: PathBuf::from("<genesis>"),
                detail: "genesis bytes fail TMFS verification".into(),
            });
        };
        let rec = WalRecord::Create {
            model_id,
            base_seed,
            name: name.to_string(),
            genesis: genesis.to_vec(),
        };
        let r = self.wal.append(self.disk.as_mut(), &rec);
        let pos = self.poison_on_err(r)?;
        self.floors.insert(model_id, pos);
        self.manifest.insert(
            model_id,
            ManifestEntry { name: name.to_string(), base_seed, ckpt_seq: genesis_seq },
        );
        self.publish_checkpoint(model_id, genesis_seq, genesis)
    }

    /// Append one sequenced update. Must be called **before** the
    /// update is applied in memory (write-ahead): an error here means
    /// the update is not durable and must not take effect.
    pub fn log_update(
        &mut self,
        model_id: u64,
        seq: u64,
        op: &WalOp,
    ) -> Result<(), StoreError> {
        self.guard()?;
        if !self.manifest.contains_key(&model_id) {
            return Err(StoreError::UnknownModel { model_id });
        }
        let rec = WalRecord::Update { model_id, seq, op: op.clone() };
        let r = self.wal.append(self.disk.as_mut(), &rec);
        self.poison_on_err(r)?;
        Ok(())
    }

    /// Publish a durable snapshot for `model_id` at `seq`, refresh the
    /// manifest, and let retention retire checkpoints and whole WAL
    /// segments nothing needs any more.
    pub fn publish_checkpoint(
        &mut self,
        model_id: u64,
        seq: u64,
        bytes: &[u8],
    ) -> Result<(), StoreError> {
        self.guard()?;
        let Some(entry) = self.manifest.get(&model_id).cloned() else {
            return Err(StoreError::UnknownModel { model_id });
        };
        let path = self.ckpt_dir.join(ckpt::ckpt_file_name(model_id, seq));
        if checkpoint::quick_check(bytes) != Some(seq) {
            return Err(StoreError::CorruptCheckpoint {
                path,
                detail: format!("bytes fail TMFS verification for seq {seq}"),
            });
        }
        let already = self
            .ckpt_files
            .get(&model_id)
            .is_some_and(|files| files.last().is_some_and(|&(s, _)| s == seq));
        let mut changed = false;
        if !already {
            let r = self.disk.write_atomic(&path, bytes);
            self.poison_on_err(r)?;
            let files = self.ckpt_files.entry(model_id).or_default();
            files.push((seq, path));
            files.sort_by_key(|&(s, _)| s);
            self.stats.ckpts_published += 1;
            changed = true;
        }
        if entry.ckpt_seq != seq {
            self.manifest.get_mut(&model_id).expect("entry checked above").ckpt_seq = seq;
            changed = true;
        }
        if changed {
            // Durable even when only the file is new (the create path
            // pre-seeds the in-memory row before calling here): the
            // manifest on disk must always name a checkpoint that
            // exists.
            let r = ckpt::write_manifest(self.disk.as_mut(), &self.root, &self.manifest);
            self.poison_on_err(r)?;
        }
        // Everything of this model at or below `seq` is now obsolete;
        // records appended later than "now" are all > seq.
        self.floors.insert(model_id, self.wal.next_pos());
        self.run_retention()
    }

    /// Flush any WAL appends the sync policy has deferred.
    pub fn sync(&mut self) -> Result<(), StoreError> {
        self.guard()?;
        let r = self.wal.sync(self.disk.as_mut());
        self.poison_on_err(r)
    }

    fn run_retention(&mut self) -> Result<(), StoreError> {
        for (_, files) in self.ckpt_files.iter_mut() {
            let r = ckpt::retire(self.disk.as_mut(), files, self.cfg.retained_ckpts);
            match r {
                Ok(n) => self.stats.ckpts_retired += n,
                Err(e) => {
                    self.poisoned = true;
                    return Err(e);
                }
            }
        }
        if let Some(&floor) = self.floors.values().min() {
            let r = self.wal.retain_from(self.disk.as_mut(), floor);
            self.poison_on_err(r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
pub(crate) fn testdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmfpga_store_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::checkpoint::snapshot_bytes;
    use crate::tm::machine::MultiTm;
    use crate::tm::params::{TmParams, TmShape};

    fn genesis(seq: u64) -> Vec<u8> {
        let s = TmShape::iris();
        let tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_online(&s);
        snapshot_bytes(&tm, &p, seq)
    }

    fn learn_op(seq: u64) -> WalOp {
        WalOp::Learn {
            label: (seq % 3) as u32,
            bits: (0..16).map(|k| (seq + k) % 2 == 0).collect(),
        }
    }

    fn cfg() -> StoreConfig {
        StoreConfig { segment_bytes: 512, ..StoreConfig::default() }
    }

    #[test]
    fn create_update_publish_reopen_round_trips() {
        let root = testdir("store_rt");
        let g = genesis(0);
        {
            let (mut st, models) =
                Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
            assert!(models.is_empty());
            st.log_create(1, "alpha", 11, &g).unwrap();
            st.log_create(2, "beta", 22, &g).unwrap();
            for seq in 1..=9u64 {
                st.log_update(1, seq, &learn_op(seq)).unwrap();
            }
            // Model 1 checkpoints at seq 8; records 1..=8 become stale.
            let ck = genesis(8);
            st.publish_checkpoint(1, 8, &ck).unwrap();
            st.log_update(2, 1, &learn_op(1)).unwrap();
        }
        let (st, mut models) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        models.sort_by_key(|m| m.id);
        assert_eq!(models.len(), 2);
        let a = &models[0];
        assert_eq!((a.id, a.name.as_str(), a.base_seed, a.ckpt_seq), (1, "alpha", 11, 8));
        assert_eq!(a.ops.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [9]);
        let b = &models[1];
        assert_eq!((b.id, b.name.as_str(), b.base_seed, b.ckpt_seq), (2, "beta", 22, 0));
        assert_eq!(b.ops.len(), 1);
        assert_eq!(st.report().models_recovered, 2);
        assert_eq!(st.report().wal_records_replayed, 2);
        assert_eq!(st.report().torn_tails_truncated, 0);
        assert_eq!(st.report().stale_manifest_entries, 0);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn stale_manifest_is_detected_and_repaired() {
        let root = testdir("store_stale");
        let g = genesis(0);
        {
            let (mut st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
            st.log_create(1, "alpha", 11, &g).unwrap();
            for seq in 1..=4u64 {
                st.log_update(1, seq, &learn_op(seq)).unwrap();
            }
            st.publish_checkpoint(1, 4, &genesis(4)).unwrap();
        }
        // Simulate the crash window: roll the manifest back to the
        // genesis row while the seq-4 checkpoint file exists.
        let mut disk = RealDisk;
        let mut rolled = BTreeMap::new();
        rolled.insert(1u64, ManifestEntry { name: "alpha".into(), base_seed: 11, ckpt_seq: 0 });
        ckpt::write_manifest(&mut disk, &root, &rolled).unwrap();
        let (st, models) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        assert_eq!(models[0].ckpt_seq, 4, "must prefer the newest durable checkpoint");
        assert_eq!(st.report().stale_manifest_entries, 1);
        assert_eq!(st.manifest()[&1].ckpt_seq, 4, "manifest repaired");
        // And the repair is durable.
        let reread = ckpt::load_manifest(&mut disk, &root).unwrap().unwrap();
        assert_eq!(reread[&1].ckpt_seq, 4);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_newest_checkpoint_falls_back_to_older() {
        let root = testdir("store_fallback");
        let g = genesis(0);
        {
            let (mut st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
            st.log_create(1, "alpha", 11, &g).unwrap();
            st.log_update(1, 1, &learn_op(1)).unwrap();
            st.publish_checkpoint(1, 1, &genesis(1)).unwrap();
            st.log_update(1, 2, &learn_op(2)).unwrap();
            st.publish_checkpoint(1, 2, &genesis(2)).unwrap();
            st.log_update(1, 3, &learn_op(3)).unwrap();
        }
        // Bit-flip the newest checkpoint file.
        let newest = root.join("ckpt").join(ckpt::ckpt_file_name(1, 2));
        let mut bytes = std::fs::read(&newest).unwrap();
        bytes[40] ^= 0x01;
        std::fs::write(&newest, &bytes).unwrap();
        let (st, models) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        assert_eq!(st.report().corrupt_checkpoints_rejected, 1);
        assert_eq!(models[0].ckpt_seq, 1, "older checkpoint stands in");
        // Replay resumes right after the older checkpoint: seqs 2, 3.
        assert_eq!(models[0].ops.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [2, 3]);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn enospc_poisons_the_store_with_typed_errors() {
        let root = testdir("store_enospc");
        let g = genesis(0);
        {
            let (mut st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
            st.log_create(1, "alpha", 11, &g).unwrap();
            st.log_update(1, 1, &learn_op(1)).unwrap();
        }
        // Boundary 1 of the reopened store's first append fails ENOSPC.
        let disk = FaultDisk::new(Some(FaultPlan { fail_at_op: 1, kind: FaultKind::Enospc }));
        let (mut st, _) = Store::open(Box::new(disk), &root, cfg()).unwrap();
        match st.log_update(1, 2, &learn_op(2)) {
            Err(StoreError::NoSpace { .. }) => {}
            other => panic!("want NoSpace, got {other:?}"),
        }
        match st.log_update(1, 2, &learn_op(2)) {
            Err(StoreError::Poisoned) => {}
            other => panic!("want Poisoned, got {other:?}"),
        }
        // Nothing was persisted: a clean reopen sees exactly seq 1.
        let (_, models) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        assert_eq!(models[0].ops.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [1]);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn crash_mid_append_leaves_a_repairable_torn_tail() {
        let root = testdir("store_crash");
        let g = genesis(0);
        {
            let (mut st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
            st.log_create(1, "alpha", 11, &g).unwrap();
            st.log_update(1, 1, &learn_op(1)).unwrap();
        }
        {
            let disk =
                FaultDisk::new(Some(FaultPlan { fail_at_op: 1, kind: FaultKind::Crash }));
            let (mut st, _) = Store::open(Box::new(disk), &root, cfg()).unwrap();
            match st.log_update(1, 2, &learn_op(2)) {
                Err(StoreError::Crashed { .. }) => {}
                other => panic!("want Crashed, got {other:?}"),
            }
        }
        let (st, models) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        assert_eq!(st.report().torn_tails_truncated, 1);
        assert_eq!(models[0].ops.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [1]);
        // The truncated log keeps working.
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn orphan_temps_are_swept() {
        let root = testdir("store_tmp");
        let g = genesis(0);
        {
            let (mut st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
            st.log_create(1, "alpha", 11, &g).unwrap();
        }
        std::fs::write(root.join("MANIFEST.tmp"), b"half").unwrap();
        std::fs::write(root.join("ckpt").join("m00000001-x.tmp"), b"half").unwrap();
        let (st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        assert_eq!(st.report().orphan_temps_removed, 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bad_names_and_configs_fail_typed() {
        let root = testdir("store_bad");
        let g = genesis(0);
        let (mut st, _) = Store::open(Box::new(RealDisk), &root, cfg()).unwrap();
        for name in ["", "has space", "semi;colon", &"x".repeat(65)] {
            match st.log_create(9, name, 1, &g) {
                Err(StoreError::BadName { .. }) => {}
                other => panic!("{name:?}: want BadName, got {other:?}"),
            }
        }
        let bad = StoreConfig { retained_ckpts: 0, ..StoreConfig::default() };
        assert!(matches!(
            Store::open(Box::new(RealDisk), &root, bad),
            Err(StoreError::BadConfig { .. })
        ));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn retention_bounds_disk_state() {
        let root = testdir("store_retention");
        let g = genesis(0);
        let small = StoreConfig {
            segment_bytes: 128,
            retained_ckpts: 2,
            ..StoreConfig::default()
        };
        let (mut st, _) = Store::open(Box::new(RealDisk), &root, small).unwrap();
        st.log_create(1, "alpha", 11, &g).unwrap();
        let mut seq = 0u64;
        for round in 0..6u64 {
            for _ in 0..8 {
                seq += 1;
                st.log_update(1, seq, &learn_op(seq)).unwrap();
            }
            st.publish_checkpoint(1, seq, &genesis(seq)).unwrap();
            let _ = round;
        }
        // Newest 2 checkpoints per model (+ none older), and the WAL
        // holds no segment that ends before the retention floor.
        let files: Vec<_> = std::fs::read_dir(root.join("ckpt"))
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(files.len(), 2, "{files:?}");
        assert!(st.stats().ckpts_retired >= 4);
        assert!(st.stats().wal.segments_retired > 0, "stale WAL segments must be retired");
        // Reopen proves the trimmed store is still complete.
        let (_, models) = Store::open(Box::new(RealDisk), &root, small).unwrap();
        assert_eq!(models[0].ckpt_seq, seq);
        assert!(models[0].ops.is_empty());
        std::fs::remove_dir_all(&root).ok();
    }
}
