//! On-disk checkpoint store + manifest.
//!
//! Checkpoint files reuse the TMFS v2 byte format from
//! `serve::checkpoint` verbatim — one file per published snapshot,
//! named `m<id:08>-<seq:020>.tmfs` so `(model_id, seq)` is recoverable
//! from the name alone and a directory listing sorts publication order.
//! Every file is published atomically: temp write → fsync → rename, so
//! a crash mid-publication leaves either the old set or the new set,
//! never a half-written snapshot (orphan temps are swept on open).
//!
//! The `MANIFEST` is a small CRC-tailed text file mapping model id →
//! (name, base_seed, newest durable checkpoint seq). It is *advisory*:
//! rebuild prefers the newest checkpoint file that actually verifies,
//! so a manifest gone stale in the crash window between checkpoint
//! publication and manifest rewrite is detected (counted) and repaired,
//! not trusted. What the manifest alone carries is model *identity*
//! (name, base_seed) after the WAL's Create record has been retired by
//! retention — which is why it is rewritten durably before any
//! retention runs.

use super::{Disk, StoreError};
use crate::util::fnv1a;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub const MANIFEST_NAME: &str = "MANIFEST";
const MANIFEST_HEADER: &str = "tmfpga-manifest v1";
const CKPT_SUFFIX: &str = ".tmfs";

/// One manifest row: identity plus the newest durable checkpoint seq.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub base_seed: u64,
    pub ckpt_seq: u64,
}

pub fn ckpt_file_name(model_id: u64, seq: u64) -> String {
    format!("m{model_id:08}-{seq:020}{CKPT_SUFFIX}")
}

pub fn parse_ckpt_name(name: &str) -> Option<(u64, u64)> {
    let stem = name.strip_prefix('m')?.strip_suffix(CKPT_SUFFIX)?;
    let (id, seq) = stem.split_once('-')?;
    if id.len() != 8 || seq.len() != 20 {
        return None;
    }
    if !id.bytes().all(|b| b.is_ascii_digit()) || !seq.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some((id.parse().ok()?, seq.parse().ok()?))
}

/// List the checkpoint directory: model id → `(seq, path)` ascending by
/// seq. Files that don't parse as checkpoint names are ignored.
#[allow(clippy::type_complexity)]
pub fn scan(
    disk: &mut dyn Disk,
    dir: &Path,
) -> Result<BTreeMap<u64, Vec<(u64, PathBuf)>>, StoreError> {
    let mut map: BTreeMap<u64, Vec<(u64, PathBuf)>> = BTreeMap::new();
    for path in disk.list(dir)? {
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if let Some((id, seq)) = parse_ckpt_name(name) {
            map.entry(id).or_default().push((seq, path));
        }
    }
    for files in map.values_mut() {
        files.sort_by_key(|&(seq, _)| seq);
    }
    Ok(map)
}

/// Delete all but the newest `keep` checkpoints of one model.
/// `files` is the ascending `(seq, path)` list from [`scan`], updated
/// in place. Returns how many files were removed.
pub fn retire(
    disk: &mut dyn Disk,
    files: &mut Vec<(u64, PathBuf)>,
    keep: usize,
) -> Result<u64, StoreError> {
    let keep = keep.max(1);
    let mut removed = 0u64;
    while files.len() > keep {
        let (_, path) = files.remove(0);
        disk.remove(&path)?;
        removed += 1;
    }
    Ok(removed)
}

fn manifest_body(entries: &BTreeMap<u64, ManifestEntry>) -> String {
    let mut body = String::new();
    body.push_str(MANIFEST_HEADER);
    body.push('\n');
    for (id, e) in entries {
        body.push_str(&format!("model {id} {} {} {}\n", e.base_seed, e.ckpt_seq, e.name));
    }
    body
}

/// Durably (atomically) rewrite the manifest.
pub fn write_manifest(
    disk: &mut dyn Disk,
    root: &Path,
    entries: &BTreeMap<u64, ManifestEntry>,
) -> Result<(), StoreError> {
    let body = manifest_body(entries);
    let mut bytes = body.into_bytes();
    let crc = fnv1a(&bytes);
    bytes.extend_from_slice(format!("crc {crc:08x}\n").as_bytes());
    disk.write_atomic(&root.join(MANIFEST_NAME), &bytes)
}

/// Read and verify the manifest. `Ok(None)` when the file doesn't
/// exist (a brand-new store); a present-but-invalid manifest is a typed
/// [`StoreError::CorruptManifest`] — the caller decides whether the WAL
/// still lets it recover.
pub fn load_manifest(
    disk: &mut dyn Disk,
    root: &Path,
) -> Result<Option<BTreeMap<u64, ManifestEntry>>, StoreError> {
    let path = root.join(MANIFEST_NAME);
    if !disk.exists(&path)? {
        return Ok(None);
    }
    let bytes = disk.read(&path)?;
    let corrupt = |detail: String| StoreError::CorruptManifest { detail };
    let text =
        std::str::from_utf8(&bytes).map_err(|e| corrupt(format!("not utf-8: {e}")))?;
    // The CRC line covers every byte before it.
    let crc_at = text
        .rfind("crc ")
        .ok_or_else(|| corrupt("missing crc line".into()))?;
    if crc_at != 0 && !text[..crc_at].ends_with('\n') {
        return Err(corrupt("crc marker not at line start".into()));
    }
    let body = &text[..crc_at];
    let crc_line = text[crc_at..]
        .strip_prefix("crc ")
        .and_then(|rest| rest.strip_suffix('\n'))
        .ok_or_else(|| corrupt("malformed crc line".into()))?;
    // Exactly 8 lowercase hex digits: `from_str_radix` alone would also
    // accept uppercase (an `a`→`A` bit flip parses to the same value).
    if crc_line.len() != 8
        || !crc_line.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
    {
        return Err(corrupt(format!("bad crc value: {crc_line:?}")));
    }
    let want = u32::from_str_radix(crc_line, 16)
        .map_err(|e| corrupt(format!("bad crc value: {e}")))?;
    let got = fnv1a(body.as_bytes());
    if got != want {
        return Err(corrupt(format!("crc mismatch (got {got:08x}, want {want:08x})")));
    }
    let mut lines = body.lines();
    if lines.next() != Some(MANIFEST_HEADER) {
        return Err(corrupt("bad header".into()));
    }
    let mut entries = BTreeMap::new();
    for line in lines {
        let mut f = line.split(' ');
        let (tag, id, base_seed, ckpt_seq, name) =
            (f.next(), f.next(), f.next(), f.next(), f.next());
        let (Some("model"), Some(id), Some(base_seed), Some(ckpt_seq), Some(name)) =
            (tag, id, base_seed, ckpt_seq, name)
        else {
            return Err(corrupt(format!("malformed line: {line:?}")));
        };
        if f.next().is_some() {
            return Err(corrupt(format!("trailing fields: {line:?}")));
        }
        let id: u64 = id.parse().map_err(|e| corrupt(format!("bad id: {e}")))?;
        let entry = ManifestEntry {
            name: name.to_string(),
            base_seed: base_seed
                .parse()
                .map_err(|e| corrupt(format!("bad base_seed: {e}")))?,
            ckpt_seq: ckpt_seq
                .parse()
                .map_err(|e| corrupt(format!("bad ckpt_seq: {e}")))?,
        };
        if entries.insert(id, entry).is_some() {
            return Err(corrupt(format!("duplicate model id {id}")));
        }
    }
    Ok(Some(entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{testdir, RealDisk};

    fn entries() -> BTreeMap<u64, ManifestEntry> {
        let mut m = BTreeMap::new();
        m.insert(1, ManifestEntry { name: "alpha".into(), base_seed: 11, ckpt_seq: 64 });
        m.insert(2, ManifestEntry { name: "beta".into(), base_seed: 22, ckpt_seq: 0 });
        m
    }

    #[test]
    fn ckpt_names_round_trip_and_sort() {
        assert_eq!(parse_ckpt_name(&ckpt_file_name(3, 128)), Some((3, 128)));
        assert_eq!(parse_ckpt_name("m00000003-x.tmfs"), None);
        assert_eq!(parse_ckpt_name("seg-00000000000000000000.wal"), None);
        // Zero-padding makes lexical order = numeric order.
        assert!(ckpt_file_name(1, 9) < ckpt_file_name(1, 10));
    }

    #[test]
    fn manifest_round_trips() {
        let dir = testdir("manifest_rt");
        let mut disk = RealDisk;
        disk.create_dir_all(&dir).unwrap();
        assert_eq!(load_manifest(&mut disk, &dir).unwrap(), None);
        let want = entries();
        write_manifest(&mut disk, &dir, &want).unwrap();
        assert_eq!(load_manifest(&mut disk, &dir).unwrap(), Some(want));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_every_bit_flip_is_detected() {
        let dir = testdir("manifest_flip");
        let mut disk = RealDisk;
        disk.create_dir_all(&dir).unwrap();
        write_manifest(&mut disk, &dir, &entries()).unwrap();
        let path = dir.join(MANIFEST_NAME);
        let clean = std::fs::read(&path).unwrap();
        for byte in 0..clean.len() {
            for bit in 0..8 {
                let mut bad = clean.clone();
                bad[byte] ^= 1 << bit;
                std::fs::write(&path, &bad).unwrap();
                match load_manifest(&mut disk, &dir) {
                    Err(StoreError::CorruptManifest { .. }) => {}
                    other => panic!("byte {byte} bit {bit}: accepted, got {other:?}"),
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retire_keeps_newest() {
        let dir = testdir("ckpt_retire");
        let mut disk = RealDisk;
        disk.create_dir_all(&dir).unwrap();
        for seq in [8u64, 16, 24, 32] {
            disk.write_atomic(&dir.join(ckpt_file_name(1, seq)), b"x").unwrap();
        }
        let mut files = scan(&mut disk, &dir).unwrap().remove(&1).unwrap();
        assert_eq!(files.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [8, 16, 24, 32]);
        assert_eq!(retire(&mut disk, &mut files, 2).unwrap(), 2);
        assert_eq!(files.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [24, 32]);
        let rescan = scan(&mut disk, &dir).unwrap().remove(&1).unwrap();
        assert_eq!(rescan.iter().map(|&(s, _)| s).collect::<Vec<_>>(), [24, 32]);
        // keep is clamped to ≥1: the newest survives any request.
        assert_eq!(retire(&mut disk, &mut files, 0).unwrap(), 1);
        assert_eq!(files.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
