//! Write-ahead log: segmented, length+CRC framed, torn-tail tolerant.
//!
//! One hub-wide log records every durable mutation — model creation
//! (with its genesis snapshot embedded, so the log alone can rebuild a
//! model that never reached a checkpoint) and every sequenced update.
//! Records live in segment files named by the **global position** of
//! their first record (`seg-<pos:020>.wal`), so the set of segments is
//! self-describing: after retention deletes a prefix, contiguity of the
//! remainder is still checkable from names + record counts alone.
//!
//! Frame layout, little-endian:
//!
//! ```text
//! len  u32   payload byte count
//! crc  u32   FNV-1a over payload (util::fnv1a)
//! payload    [len bytes]
//! ```
//!
//! Torn-tail semantics (the load-bearing invariant): appends are
//! prefix-atomic — a crashed `write` leaves a *prefix* of the frame, so
//! a partial trailing record is always an **incomplete** frame (header
//! short, or payload extending past end-of-file). On open, an
//! incomplete frame at the physical tail of the *final* segment is
//! truncated away and counted; it can only be the unacknowledged
//! in-flight record. A **complete** frame whose CRC mismatches can not
//! be produced by tearing — it is bit corruption — and is a typed
//! error, as is any damage in a non-final segment.

use super::{Disk, StoreError, SyncPolicy};
use crate::util::fnv1a;
use std::path::{Path, PathBuf};

const SEG_PREFIX: &str = "seg-";
const SEG_SUFFIX: &str = ".wal";

/// Payloads beyond this are corruption, not data: the largest real
/// record is a genesis snapshot, far below this bound. A length field
/// this large therefore fails typed instead of being mistaken for an
/// (arbitrarily long) torn tail.
const MAX_RECORD_BYTES: u32 = 64 * 1024 * 1024;

/// A sequenced model mutation as logged on disk. Deliberately
/// wire-level (label + raw feature bits, not a packed `Input`): the
/// store stays independent of the TM crate types, and the hub
/// reconstructs `Input::pack(shape, bits)` on replay — exact, because
/// every derived word of an `Input` is a function of its feature bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalOp {
    Learn { label: u32, bits: Vec<bool> },
    ClauseFault { class: u32, clause: u32, force: Option<bool> },
}

/// One durable log record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    /// A model joined the hub. Carries the genesis TMFS v2 snapshot so
    /// the log is self-contained until the first durable checkpoint.
    Create { model_id: u64, base_seed: u64, name: String, genesis: Vec<u8> },
    /// One sequenced update applied to a model.
    Update { model_id: u64, seq: u64, op: WalOp },
}

const TAG_CREATE: u8 = 1;
const TAG_UPDATE: u8 = 2;
const OP_LEARN: u8 = 1;
const OP_CLAUSE_FAULT: u8 = 2;
const FORCE_NONE: u8 = 0;
const FORCE_EXCLUDE: u8 = 1;
const FORCE_INCLUDE: u8 = 2;

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Serialize a record payload (the framed bytes are `frame()`'s job).
pub fn encode(rec: &WalRecord) -> Vec<u8> {
    let mut buf = Vec::new();
    match rec {
        WalRecord::Create { model_id, base_seed, name, genesis } => {
            buf.push(TAG_CREATE);
            push_u64(&mut buf, *model_id);
            push_u64(&mut buf, *base_seed);
            push_u32(&mut buf, name.len() as u32);
            buf.extend_from_slice(name.as_bytes());
            push_u32(&mut buf, genesis.len() as u32);
            buf.extend_from_slice(genesis);
        }
        WalRecord::Update { model_id, seq, op } => {
            buf.push(TAG_UPDATE);
            push_u64(&mut buf, *model_id);
            push_u64(&mut buf, *seq);
            match op {
                WalOp::Learn { label, bits } => {
                    buf.push(OP_LEARN);
                    push_u32(&mut buf, *label);
                    push_u32(&mut buf, bits.len() as u32);
                    let mut byte = 0u8;
                    for (k, &b) in bits.iter().enumerate() {
                        if b {
                            byte |= 1 << (k % 8);
                        }
                        if k % 8 == 7 {
                            buf.push(byte);
                            byte = 0;
                        }
                    }
                    if bits.len() % 8 != 0 {
                        buf.push(byte);
                    }
                }
                WalOp::ClauseFault { class, clause, force } => {
                    buf.push(OP_CLAUSE_FAULT);
                    push_u32(&mut buf, *class);
                    push_u32(&mut buf, *clause);
                    buf.push(match force {
                        None => FORCE_NONE,
                        Some(false) => FORCE_EXCLUDE,
                        Some(true) => FORCE_INCLUDE,
                    });
                }
            }
        }
    }
    buf
}

/// Bounds-checked little-endian reader over a record payload.
struct Rd<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.bytes.len() - self.pos < n {
            return Err(format!(
                "truncated payload ({} bytes left at offset {}, want {n})",
                self.bytes.len() - self.pos,
                self.pos
            ));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
}

/// Decode a payload that already passed its frame CRC. Any failure here
/// is therefore bit-exact corruption (or an encoder bug), never a torn
/// write; the caller wraps it as a typed `CorruptRecord`.
pub fn decode(payload: &[u8]) -> Result<WalRecord, String> {
    let mut r = Rd { bytes: payload, pos: 0 };
    let rec = match r.u8()? {
        TAG_CREATE => {
            let model_id = r.u64()?;
            let base_seed = r.u64()?;
            let name_len = r.u32()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .map_err(|e| format!("create name not utf-8: {e}"))?
                .to_string();
            let genesis_len = r.u32()? as usize;
            let genesis = r.take(genesis_len)?.to_vec();
            WalRecord::Create { model_id, base_seed, name, genesis }
        }
        TAG_UPDATE => {
            let model_id = r.u64()?;
            let seq = r.u64()?;
            let op = match r.u8()? {
                OP_LEARN => {
                    let label = r.u32()?;
                    let nbits = r.u32()? as usize;
                    let packed = r.take(nbits.div_ceil(8))?;
                    let bits =
                        (0..nbits).map(|k| packed[k / 8] >> (k % 8) & 1 == 1).collect();
                    WalOp::Learn { label, bits }
                }
                OP_CLAUSE_FAULT => {
                    let class = r.u32()?;
                    let clause = r.u32()?;
                    let force = match r.u8()? {
                        FORCE_NONE => None,
                        FORCE_EXCLUDE => Some(false),
                        FORCE_INCLUDE => Some(true),
                        v => return Err(format!("bad force code {v}")),
                    };
                    WalOp::ClauseFault { class, clause, force }
                }
                v => return Err(format!("bad op tag {v}")),
            };
            WalRecord::Update { model_id, seq, op }
        }
        v => return Err(format!("bad record tag {v}")),
    };
    if r.pos != payload.len() {
        return Err(format!(
            "trailing garbage: {} bytes past record end",
            payload.len() - r.pos
        ));
    }
    Ok(rec)
}

fn frame(payload: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(payload.len() + 8);
    push_u32(&mut f, payload.len() as u32);
    push_u32(&mut f, fnv1a(payload));
    f.extend_from_slice(payload);
    f
}

fn seg_path(dir: &Path, first_pos: u64) -> PathBuf {
    dir.join(format!("{SEG_PREFIX}{first_pos:020}{SEG_SUFFIX}"))
}

fn parse_seg_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix(SEG_PREFIX)?.strip_suffix(SEG_SUFFIX)?;
    if digits.len() != 20 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// What `Wal::open` observed and repaired on the way up.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WalOpenReport {
    pub segments_scanned: u64,
    pub torn_tails_truncated: u64,
    /// Bytes cut from the final segment when a torn tail was truncated.
    pub torn_bytes_dropped: u64,
}

/// Lifetime write counters, for exact accounting in tests/telemetry.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WalStats {
    pub appends: u64,
    pub syncs: u64,
    pub rotations: u64,
    pub segments_retired: u64,
}

/// The append side of the log. All disk access goes through the
/// caller-supplied [`Disk`] so faults can be injected at every write
/// boundary.
#[derive(Debug)]
pub struct Wal {
    dir: PathBuf,
    segment_bytes: u64,
    sync_policy: SyncPolicy,
    /// First record position of every live segment, ascending; the last
    /// entry is the append tail. Non-empty once open returns.
    segs: Vec<u64>,
    /// Byte length of the tail segment.
    seg_len: u64,
    /// Global position of the next record to append.
    next_pos: u64,
    /// Appends not yet covered by a sync.
    dirty: u64,
    stats: WalStats,
}

impl Wal {
    /// Scan (and, for a torn tail, repair) the log directory, returning
    /// the writer positioned at the tail plus every surviving record in
    /// position order.
    #[allow(clippy::type_complexity)]
    pub fn open(
        disk: &mut dyn Disk,
        dir: &Path,
        segment_bytes: u64,
        sync_policy: SyncPolicy,
    ) -> Result<(Wal, Vec<(u64, WalRecord)>, WalOpenReport), StoreError> {
        disk.create_dir_all(dir)?;
        let mut segs: Vec<u64> = Vec::new();
        for path in disk.list(dir)? {
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if let Some(first) = parse_seg_name(name) {
                segs.push(first);
            }
        }
        segs.sort_unstable();

        let mut report = WalOpenReport::default();
        let mut records: Vec<(u64, WalRecord)> = Vec::new();
        let mut pos = segs.first().copied().unwrap_or(0);
        let mut tail_len = 0u64;
        for (i, &first) in segs.iter().enumerate() {
            let path = seg_path(dir, first);
            let is_final = i + 1 == segs.len();
            if first != pos {
                return Err(StoreError::MissingSegment {
                    expected_pos: pos,
                    found: path,
                });
            }
            let bytes = disk.read(&path)?;
            report.segments_scanned += 1;
            let mut off = 0usize;
            loop {
                let rem = bytes.len() - off;
                if rem == 0 {
                    break;
                }
                // Header (or its prefix): a short header can only be a
                // torn tail, and only legal at the final segment's end.
                let complete_header = rem >= 8;
                let len = if complete_header {
                    u32::from_le_bytes([
                        bytes[off],
                        bytes[off + 1],
                        bytes[off + 2],
                        bytes[off + 3],
                    ])
                } else {
                    0
                };
                if complete_header && len > MAX_RECORD_BYTES {
                    // A torn write leaves the *true* length field (or no
                    // length field at all); an absurd length is bit
                    // corruption.
                    return Err(StoreError::CorruptRecord {
                        segment: path,
                        offset: off as u64,
                        detail: format!("record length {len} exceeds maximum"),
                    });
                }
                let complete = complete_header && rem >= 8 + len as usize;
                if !complete {
                    if !is_final {
                        return Err(StoreError::CorruptRecord {
                            segment: path,
                            offset: off as u64,
                            detail: format!(
                                "incomplete frame ({rem} bytes) inside non-final segment"
                            ),
                        });
                    }
                    // Torn tail: the unacknowledged in-flight append.
                    disk.truncate(&path, off as u64)?;
                    report.torn_tails_truncated += 1;
                    report.torn_bytes_dropped += rem as u64;
                    tail_len = off as u64;
                    break;
                }
                let want_crc = u32::from_le_bytes([
                    bytes[off + 4],
                    bytes[off + 5],
                    bytes[off + 6],
                    bytes[off + 7],
                ]);
                let payload = &bytes[off + 8..off + 8 + len as usize];
                if fnv1a(payload) != want_crc {
                    return Err(StoreError::CorruptRecord {
                        segment: path,
                        offset: off as u64,
                        detail: "payload CRC mismatch".into(),
                    });
                }
                let rec = decode(payload).map_err(|detail| StoreError::CorruptRecord {
                    segment: path.clone(),
                    offset: off as u64,
                    detail,
                })?;
                records.push((pos, rec));
                pos += 1;
                off += 8 + len as usize;
                if is_final {
                    tail_len = off as u64;
                }
            }
        }
        if segs.is_empty() {
            segs.push(0);
        }
        let wal = Wal {
            dir: dir.to_path_buf(),
            segment_bytes: segment_bytes.max(1),
            sync_policy,
            segs,
            seg_len: tail_len,
            next_pos: pos,
            dirty: 0,
            stats: WalStats::default(),
        };
        Ok((wal, records, report))
    }

    /// Global position the next append will get.
    pub fn next_pos(&self) -> u64 {
        self.next_pos
    }

    pub fn stats(&self) -> WalStats {
        self.stats
    }

    /// First positions of the live segments, ascending (tests).
    pub fn segments(&self) -> &[u64] {
        &self.segs
    }

    fn tail_path(&self) -> PathBuf {
        seg_path(&self.dir, *self.segs.last().expect("wal always has a tail segment"))
    }

    /// Append one record; returns its global position. Durability is
    /// governed by the sync policy; an error leaves the record
    /// non-durable and the caller must treat the write as failed.
    pub fn append(&mut self, disk: &mut dyn Disk, rec: &WalRecord) -> Result<u64, StoreError> {
        if self.seg_len >= self.segment_bytes {
            // Rotate. The outgoing segment is synced first so EveryN
            // never leaves dirty bytes behind a segment boundary.
            if self.dirty > 0 {
                self.sync(disk)?;
            }
            self.segs.push(self.next_pos);
            self.seg_len = 0;
            self.stats.rotations += 1;
        }
        let path = self.tail_path();
        let f = frame(&encode(rec));
        disk.append(&path, &f)?;
        self.seg_len += f.len() as u64;
        let pos = self.next_pos;
        self.next_pos += 1;
        self.stats.appends += 1;
        self.dirty += 1;
        match self.sync_policy {
            SyncPolicy::Always => self.sync(disk)?,
            SyncPolicy::EveryN(n) => {
                if self.dirty >= n.max(1) {
                    self.sync(disk)?;
                }
            }
            SyncPolicy::OnDemand => {}
        }
        Ok(pos)
    }

    /// Flush the tail segment to stable storage.
    pub fn sync(&mut self, disk: &mut dyn Disk) -> Result<(), StoreError> {
        if self.dirty == 0 {
            return Ok(());
        }
        disk.sync(&self.tail_path())?;
        self.dirty = 0;
        self.stats.syncs += 1;
        Ok(())
    }

    /// Delete whole segments whose records all lie below `floor` (the
    /// oldest position any model still needs). The tail segment is
    /// never deleted. Returns the number of segments removed.
    pub fn retain_from(&mut self, disk: &mut dyn Disk, floor: u64) -> Result<u64, StoreError> {
        let mut removed = 0u64;
        while self.segs.len() >= 2 && self.segs[1] <= floor {
            let path = seg_path(&self.dir, self.segs[0]);
            disk.remove(&path)?;
            self.segs.remove(0);
            removed += 1;
            self.stats.segments_retired += 1;
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{testdir, RealDisk, StoreError, SyncPolicy};

    fn learn(model_id: u64, seq: u64) -> WalRecord {
        let bits = (0..16).map(|k| (seq + k) % 3 == 0).collect();
        WalRecord::Update { model_id, seq, op: WalOp::Learn { label: (seq % 3) as u32, bits } }
    }

    #[test]
    fn record_codec_round_trips() {
        let recs = vec![
            WalRecord::Create {
                model_id: 7,
                base_seed: 0xDEAD_BEEF,
                name: "alpha".into(),
                genesis: vec![1, 2, 3, 4, 5],
            },
            learn(7, 1),
            WalRecord::Update {
                model_id: 7,
                seq: 2,
                op: WalOp::ClauseFault { class: 1, clause: 3, force: Some(true) },
            },
            WalRecord::Update {
                model_id: 8,
                seq: 1,
                op: WalOp::ClauseFault { class: 0, clause: 0, force: None },
            },
        ];
        for rec in &recs {
            assert_eq!(&decode(&encode(rec)).unwrap(), rec);
        }
    }

    #[test]
    fn append_reopen_round_trips_across_rotation() {
        let dir = testdir("wal_roundtrip");
        let mut disk = RealDisk;
        let (mut wal, recs, rep) =
            Wal::open(&mut disk, &dir, 256, SyncPolicy::Always).unwrap();
        assert!(recs.is_empty());
        assert_eq!(rep.torn_tails_truncated, 0);
        let mut want = Vec::new();
        for seq in 1..=40u64 {
            let rec = learn(1, seq);
            let pos = wal.append(&mut disk, &rec).unwrap();
            assert_eq!(pos, seq - 1);
            want.push((pos, rec));
        }
        assert!(wal.stats().rotations > 0, "256-byte segments must rotate");
        let (wal2, got, rep2) = Wal::open(&mut disk, &dir, 256, SyncPolicy::Always).unwrap();
        assert_eq!(got, want);
        assert_eq!(wal2.next_pos(), 40);
        assert_eq!(rep2.torn_tails_truncated, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_and_counted() {
        let dir = testdir("wal_torn");
        let mut disk = RealDisk;
        let (mut wal, _, _) = Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always).unwrap();
        for seq in 1..=5u64 {
            wal.append(&mut disk, &learn(1, seq)).unwrap();
        }
        // Tear the tail: append a frame prefix by hand.
        let seg = dir.join("seg-00000000000000000000.wal");
        let full = frame(&encode(&learn(1, 6)));
        for cut in [1, 4, 7, 8, full.len() - 1] {
            let clean = std::fs::read(&seg).unwrap();
            let mut torn = clean.clone();
            torn.extend_from_slice(&full[..cut]);
            std::fs::write(&seg, &torn).unwrap();
            let (wal2, recs, rep) =
                Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always).unwrap();
            assert_eq!(recs.len(), 5, "cut={cut}");
            assert_eq!(rep.torn_tails_truncated, 1, "cut={cut}");
            assert_eq!(rep.torn_bytes_dropped, cut as u64, "cut={cut}");
            assert_eq!(wal2.next_pos(), 5);
            // The repair is physical: the file is clean again.
            assert_eq!(std::fs::read(&seg).unwrap(), clean, "cut={cut}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn interior_corruption_is_a_typed_error() {
        let dir = testdir("wal_corrupt");
        let mut disk = RealDisk;
        let (mut wal, _, _) = Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always).unwrap();
        for seq in 1..=5u64 {
            wal.append(&mut disk, &learn(1, seq)).unwrap();
        }
        let seg = dir.join("seg-00000000000000000000.wal");
        let clean = std::fs::read(&seg).unwrap();
        // Flip one payload bit in the middle record: complete frame, bad CRC.
        let mut bad = clean.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        std::fs::write(&seg, &bad).unwrap();
        match Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always) {
            Err(StoreError::CorruptRecord { .. }) => {}
            other => panic!("want CorruptRecord, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_segment_is_a_typed_error() {
        let dir = testdir("wal_gap");
        let mut disk = RealDisk;
        let (mut wal, _, _) = Wal::open(&mut disk, &dir, 64, SyncPolicy::Always).unwrap();
        for seq in 1..=30u64 {
            wal.append(&mut disk, &learn(1, seq)).unwrap();
        }
        let segs: Vec<u64> = wal.segments().to_vec();
        assert!(segs.len() >= 3, "need ≥3 segments, got {segs:?}");
        // Delete a middle segment.
        std::fs::remove_file(seg_path(&dir, segs[1])).unwrap();
        match Wal::open(&mut disk, &dir, 64, SyncPolicy::Always) {
            Err(StoreError::MissingSegment { expected_pos, .. }) => {
                assert_eq!(expected_pos, segs[1]);
            }
            other => panic!("want MissingSegment, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retention_drops_only_wholly_stale_segments() {
        let dir = testdir("wal_retain");
        let mut disk = RealDisk;
        let (mut wal, _, _) = Wal::open(&mut disk, &dir, 64, SyncPolicy::Always).unwrap();
        for seq in 1..=30u64 {
            wal.append(&mut disk, &learn(1, seq)).unwrap();
        }
        let segs: Vec<u64> = wal.segments().to_vec();
        assert!(segs.len() >= 3);
        // Floor below the second segment keeps everything.
        assert_eq!(wal.retain_from(&mut disk, segs[1] - 1).unwrap(), 0);
        // Floor at the third segment's start drops the first two.
        let removed = wal.retain_from(&mut disk, segs[2]).unwrap();
        assert_eq!(removed, 2);
        // Reopen still sees a contiguous, scannable suffix.
        let (wal2, recs, _) = Wal::open(&mut disk, &dir, 64, SyncPolicy::Always).unwrap();
        assert_eq!(wal2.next_pos(), 30);
        assert_eq!(recs.first().unwrap().0, segs[2]);
        // The tail segment is never deleted, whatever the floor.
        let mut wal3 = wal2;
        wal3.retain_from(&mut disk, u64::MAX).unwrap();
        assert_eq!(wal3.segments().len(), 1);
        let (_, recs3, _) = Wal::open(&mut disk, &dir, 64, SyncPolicy::Always).unwrap();
        assert!(!recs3.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_bit_flip_in_a_sealed_log_is_detected() {
        let dir = testdir("wal_bitflip");
        let mut disk = RealDisk;
        let (mut wal, _, _) = Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always).unwrap();
        for seq in 1..=3u64 {
            wal.append(&mut disk, &learn(1, seq)).unwrap();
        }
        let seg = dir.join("seg-00000000000000000000.wal");
        let clean = std::fs::read(&seg).unwrap();
        let (_, want, _) = Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always).unwrap();
        for byte in 0..clean.len() {
            for bit in 0..8 {
                let mut bad = clean.clone();
                bad[byte] ^= 1 << bit;
                std::fs::write(&seg, &bad).unwrap();
                // Every flip either fails typed or — if it hits a length
                // field such that the frame no longer fits — truncates
                // as a torn tail, losing only a suffix. It must never
                // yield a record set that silently *differs* within the
                // surviving prefix.
                match Wal::open(&mut disk, &dir, 1 << 20, SyncPolicy::Always) {
                    Err(StoreError::CorruptRecord { .. }) => {}
                    Err(other) => panic!("byte {byte} bit {bit}: unexpected {other:?}"),
                    Ok((_, got, rep)) => {
                        assert!(
                            rep.torn_tails_truncated == 1,
                            "byte {byte} bit {bit}: accepted a flipped log"
                        );
                        assert!(got.len() < want.len());
                        assert_eq!(got, want[..got.len()], "byte {byte} bit {bit}");
                        // Undo the truncation's damage for the next iteration.
                    }
                }
                std::fs::write(&seg, &clean).unwrap();
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
