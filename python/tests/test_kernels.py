"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps randomize shapes, states, inputs, masks and randomness;
golden tests pin the contract's edge cases (empty clauses, fault gates,
saturation, selection boundaries).
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not in the offline image; property sweeps skip"
)
from hypothesis import given, settings, strategies as st

from compile.kernels import clause as kclause
from compile.kernels import feedback as kfeedback
from compile.kernels import ref


def rand_case(seed, classes, clauses, features, states):
    rng = np.random.default_rng(seed)
    lits = 2 * features
    cjl = (classes, clauses, lits)
    state = rng.integers(0, 2 * states, size=cjl).astype(np.int32)
    xbits = rng.integers(0, 2, size=features)
    x = np.concatenate([xbits, 1 - xbits]).astype(np.float32)
    # ~10% faulty TAs.
    and_mask = (rng.random(cjl) > 0.05).astype(np.float32)
    or_mask = ((rng.random(cjl) < 0.05) * and_mask).astype(np.float32)
    active_clauses = 2 * rng.integers(1, clauses // 2 + 1)
    clause_mask = (np.arange(clauses) < active_clauses).astype(np.float32)
    active_classes = rng.integers(1, classes + 1)
    class_mask = (np.arange(classes) < active_classes).astype(np.float32)
    return state, x, and_mask, or_mask, clause_mask, class_mask


shape_st = st.tuples(
    st.integers(1, 4),            # classes
    st.sampled_from([2, 4, 8, 16]),  # clauses (even)
    st.integers(1, 20),           # features
    st.sampled_from([4, 100]),    # states per side
)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), shp=shape_st,
       train_mode=st.booleans())
def test_clause_kernel_matches_ref(seed, shp, train_mode):
    classes, clauses, features, states = shp
    state, x, am, om, clm, cm = rand_case(seed, *shp)
    got = kclause.clause_outputs(state, x, am, om, clm, cm,
                                 thresh=states, train_mode=train_mode)
    want = ref.clause_outputs(state, x, am, om, clm, cm,
                              states, train_mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), shp=shape_st,
       t=st.integers(1, 20),
       s=st.floats(1.0, 10.0, allow_nan=False))
def test_train_kernel_matches_ref(seed, shp, t, s):
    classes, clauses, features, states = shp
    state, x, am, om, clm, cm = rand_case(seed, *shp)
    rng = np.random.default_rng(seed ^ 0xFEED)
    sign = np.zeros(classes, np.float32)
    target = rng.integers(0, classes)
    sign[target] = 1.0
    if classes > 1:
        neg = (target + 1 + rng.integers(0, classes - 1)) % classes
        if neg != target:
            sign[neg] = -1.0
    clause_rand = rng.random((classes, clauses)).astype(np.float32)
    ta_rand = rng.random((classes, clauses, 2 * features)).astype(np.float32)
    p_re = np.float32((s - 1.0) / s)
    p_wk = np.float32(1.0 / s)
    scalars = np.array([t, p_re, p_wk], np.float32)

    got = kfeedback.train_step(state, x, sign, clause_rand, ta_rand,
                               am, om, clm, cm, scalars, thresh=states)
    want = ref.train_step(state, x, sign, clause_rand, ta_rand,
                          am, om, clm, cm,
                          np.float32(t), p_re, p_wk, states)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def iris_case(seed=0):
    return rand_case(seed, 3, 16, 16, 100)


def test_empty_clause_convention():
    state, x, am, om, clm, cm = iris_case()
    state = np.zeros_like(state)  # everything excluded
    train = kclause.clause_outputs(state, x, am * 0 + 1, om * 0, clm * 0 + 1,
                                   cm * 0 + 1, thresh=100, train_mode=True)
    infer = kclause.clause_outputs(state, x, am * 0 + 1, om * 0, clm * 0 + 1,
                                   cm * 0 + 1, thresh=100, train_mode=False)
    assert np.all(np.asarray(train) == 1.0), "empty clause fires in train"
    assert np.all(np.asarray(infer) == 0.0), "empty clause silent in infer"


def test_fault_gates_force_actions():
    state, x, _, _, clm, cm = iris_case()
    state = np.zeros_like(state)           # all exclude
    ones = np.ones_like(state, np.float32)
    zeros = np.zeros_like(state, np.float32)
    clm, cm = np.ones(16, np.float32), np.ones(3, np.float32)
    # Stuck-at-1 on every TA: clause includes every literal; literal k and
    # its complement can't both be 1 -> every clause blocked.
    out = kclause.clause_outputs(state, x, ones, ones, clm, cm,
                                 thresh=100, train_mode=True)
    assert np.all(np.asarray(out) == 0.0)
    # Stuck-at-0 on every TA with fully-included state: clause empty again.
    state_inc = np.full_like(state, 199)
    out = kclause.clause_outputs(state_inc, x, zeros, zeros, clm, cm,
                                 thresh=100, train_mode=False)
    assert np.all(np.asarray(out) == 0.0)


def test_saturation_at_bounds():
    _, x, am, om, clm, cm = iris_case()
    am, om = am * 0 + 1, om * 0
    clm, cm = np.ones(16, np.float32), np.ones(3, np.float32)
    # All states at max; Type II cannot push further.
    state = np.full((3, 16, 32), 199, np.int32)
    sign = np.array([1.0, -1.0, 0.0], np.float32)
    clause_rand = np.zeros((3, 16), np.float32)   # select everything
    ta_rand = np.zeros((3, 16, 32), np.float32)   # all events fire
    scalars = np.array([15.0, 1.0, 1.0], np.float32)
    new = kfeedback.train_step(state, x, sign, clause_rand, ta_rand,
                               am, om, clm, cm, scalars, thresh=100)
    assert np.asarray(new).max() <= 199
    # All states at 0; Type I weaken cannot push below 0.
    state0 = np.zeros((3, 16, 32), np.int32)
    new0 = kfeedback.train_step(state0, x, sign, clause_rand, ta_rand,
                                am, om, clm, cm, scalars, thresh=100)
    assert np.asarray(new0).min() >= 0


def test_no_selection_no_change():
    state, x, am, om, clm, cm = iris_case(3)
    sign = np.array([1.0, -1.0, 0.0], np.float32)
    clause_rand = np.ones((3, 16), np.float32)    # never < p_sel <= 1
    ta_rand = np.zeros((3, 16, 32), np.float32)
    scalars = np.array([15.0, 0.5, 0.5], np.float32)
    new = kfeedback.train_step(state, x, sign, clause_rand, ta_rand,
                               am, om, clm, cm, scalars, thresh=100)
    np.testing.assert_array_equal(np.asarray(new), state)


def test_votes_polarity_and_clamp():
    out = jnp.ones((2, 6), jnp.float32)   # 3 positive, 3 negative clauses
    v = kclause.votes(out, jnp.float32(15.0))
    np.testing.assert_array_equal(np.asarray(v), [0, 0])
    out = jnp.tile(jnp.array([1.0, 0.0]), (1, 3)).reshape(1, 6)
    v = kclause.votes(out, jnp.float32(2.0))
    np.testing.assert_array_equal(np.asarray(v), [2])  # 3 clamps to 2
