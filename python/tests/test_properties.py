"""Cross-cutting property tests for the L1/L2 stack.

Hypothesis sweeps beyond the kernel-vs-oracle checks in test_kernels.py:
invariants of the training dynamics (saturation, monotonicity of the
selection probability), batch/single-consistency of the eval graph, and
mask semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not in the offline image; property sweeps skip"
)
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def mk_state(rng, shape, lo=0, hi=None):
    hi = hi if hi is not None else 2 * shape.states
    return rng.integers(lo, hi, size=(shape.classes, shape.clauses,
                                      shape.literals)).astype(np.int32)


def mk_x(rng, shape):
    bits = rng.integers(0, 2, size=shape.features)
    return np.concatenate([bits, 1 - bits]).astype(np.float32)


def identity_masks(shape):
    cjl = (shape.classes, shape.clauses, shape.literals)
    return (np.ones(cjl, np.float32), np.zeros(cjl, np.float32),
            np.ones(shape.clauses, np.float32),
            np.ones(shape.classes, np.float32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_train_step_moves_states_by_at_most_one(seed):
    shape = model.IRIS
    rng = np.random.default_rng(seed)
    state = mk_state(rng, shape)
    x = mk_x(rng, shape)
    am, om, clm, cm = identity_masks(shape)
    sign = np.array([1.0, -1.0, 0.0], np.float32)
    step = model.tm_train_step(shape)
    new = np.asarray(step(
        state, x, sign,
        rng.random((3, 16), dtype=np.float32),
        rng.random((3, 16, 32), dtype=np.float32),
        am, om, clm, cm,
        np.array([15.0, 0.27, 0.73], np.float32)))
    delta = new - state
    assert delta.min() >= -1 and delta.max() <= 1
    assert new.min() >= 0 and new.max() <= 2 * shape.states - 1
    # Sign-0 class untouched.
    np.testing.assert_array_equal(new[2], state[2])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_eval_batch_matches_single_infer(seed):
    shape = model.IRIS
    rng = np.random.default_rng(seed)
    state = mk_state(rng, shape)
    am, om, clm, cm = identity_masks(shape)
    batch = 8
    xs = np.stack([mk_x(rng, shape) for _ in range(batch)])
    labels = rng.integers(0, 3, size=batch).astype(np.int32)
    valid = np.ones(batch, np.float32)
    ev = model.tm_eval_batch(shape, batch)
    preds, correct = ev(state, xs, labels, valid, am, om, clm, cm,
                        jnp.float32(15.0))
    infer = model.tm_infer(shape)
    expect = np.array([
        int(infer(state, xs[i], am, om, clm, cm, jnp.float32(15.0))[1])
        for i in range(batch)
    ])
    np.testing.assert_array_equal(np.asarray(preds), expect)
    assert int(correct) == int(np.sum(expect == labels))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_selection_probability_monotone_in_votes(seed):
    """ref-level invariant: for the target class, p_sel falls as the
    class's vote sum rises — the threshold feedback-damping mechanism the
    paper leans on ("training ... linked to a threshold hyper-parameter
    which is used to reduce the probability of issuing feedback as the TM
    becomes trained further")."""
    t = 15.0
    sums = np.arange(-15, 16, dtype=np.float32)
    p_target = (t - 1.0 * sums) / (2 * t)
    assert np.all(np.diff(p_target) < 0)
    p_contrast = (t + 1.0 * sums) / (2 * t)
    assert np.all(np.diff(p_contrast) > 0)
    assert np.all((p_target >= 0) & (p_target <= 1))
    _ = seed


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), killed=st.integers(0, 15))
def test_clause_mask_removes_exactly_that_clause(seed, killed):
    shape = model.IRIS
    rng = np.random.default_rng(seed)
    # Fully-included random states so most clauses are non-empty.
    state = mk_state(rng, shape, lo=shape.states - 5, hi=shape.states + 5)
    x = mk_x(rng, shape)
    am, om, clm, cm = identity_masks(shape)
    out_full = ref.clause_outputs(state, x, am, om, clm, cm,
                                  shape.states, train_mode=True)
    clm2 = clm.copy()
    clm2[killed] = 0.0
    out_masked = ref.clause_outputs(state, x, am, om, clm2, cm,
                                    shape.states, train_mode=True)
    diff = np.asarray(out_full) - np.asarray(out_masked)
    # Only column `killed` can change, and only 1 -> 0.
    assert np.all(diff[:, np.arange(16) != killed] == 0)
    assert np.all(diff[:, killed] >= 0)
    assert np.all(np.asarray(out_masked)[:, killed] == 0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_stuck_at_0_never_increases_clause_output(seed):
    """Monotonicity: forcing TA outputs to 0 can only make clauses fire
    *more* (fewer constraints) in train mode; in infer mode a clause can
    also fall silent by becoming empty — but a firing non-empty clause
    never gains new blockers."""
    shape = model.IRIS
    rng = np.random.default_rng(seed)
    state = mk_state(rng, shape)
    x = mk_x(rng, shape)
    am, om, clm, cm = identity_masks(shape)
    out_clean = ref.clause_outputs(state, x, am, om, clm, cm,
                                   shape.states, train_mode=True)
    am2 = (rng.random(am.shape) > 0.3).astype(np.float32)  # 30% stuck-at-0
    out_faulty = ref.clause_outputs(state, x, am2, om, clm, cm,
                                    shape.states, train_mode=True)
    # Train mode: removing includes can only keep or raise the output.
    assert np.all(np.asarray(out_faulty) >= np.asarray(out_clean))


def test_infer_train_mode_outputs_differ_only_on_empty_clauses():
    shape = model.IRIS
    rng = np.random.default_rng(0)
    state = mk_state(rng, shape)
    x = mk_x(rng, shape)
    am, om, clm, cm = identity_masks(shape)
    train = np.asarray(ref.clause_outputs(state, x, am, om, clm, cm,
                                          shape.states, True))
    infer = np.asarray(ref.clause_outputs(state, x, am, om, clm, cm,
                                          shape.states, False))
    eff = np.asarray(ref.effective_actions(state, am, om, shape.states))
    empty = eff.max(axis=2) < 0.5
    # They agree everywhere a clause is non-empty.
    assert np.array_equal(train[~empty], infer[~empty])
    # Empty clauses: 1 in train, 0 in infer.
    assert np.all(train[empty] == 1.0)
    assert np.all(infer[empty] == 0.0)
