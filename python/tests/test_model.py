"""L2 model tests: shapes, dtypes, argmax tie-breaks, batched accuracy."""

import jax.numpy as jnp
import numpy as np

from compile import model


def mk_masks(shape):
    cjl = (shape.classes, shape.clauses, shape.literals)
    return (np.ones(cjl, np.float32), np.zeros(cjl, np.float32),
            np.ones(shape.clauses, np.float32),
            np.ones(shape.classes, np.float32))


def test_infer_shapes_and_tiebreak():
    shape = model.IRIS
    infer = model.tm_infer(shape)
    state = np.full((3, 16, 32), 99, np.int32)  # untrained
    xbits = np.zeros(16, np.int32)
    x = np.concatenate([xbits, 1 - xbits]).astype(np.float32)
    am, om, clm, cm = mk_masks(shape)
    v, pred = infer(state, x, am, om, clm, cm, jnp.float32(15.0))
    assert v.shape == (3,) and v.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(v), [0, 0, 0])
    assert int(pred) == 0, "tie breaks to the lowest class index"


def test_infer_masked_class_never_predicted():
    shape = model.IRIS
    infer = model.tm_infer(shape)
    # Teach class 2's positive clause 0 an always-true pattern…
    state = np.full((3, 16, 32), 99, np.int32)
    state[2, 0, 0] = 150
    xbits = np.ones(16, np.int32)
    x = np.concatenate([xbits, 1 - xbits]).astype(np.float32)
    am, om, clm, cm = mk_masks(shape)
    v, pred = infer(state, x, am, om, clm, cm, jnp.float32(15.0))
    assert int(pred) == 2
    # …then mask class 2 out (over-provisioned class).
    cm = np.array([1.0, 1.0, 0.0], np.float32)
    v, pred = infer(state, x, am, om, clm, cm, jnp.float32(15.0))
    assert int(pred) != 2
    assert int(v[2]) == 0


def test_clause_number_port_gates_votes():
    shape = model.IRIS
    infer = model.tm_infer(shape)
    state = np.full((3, 16, 32), 99, np.int32)
    state[0, 14, 0] = 150  # positive clause 14 includes literal 0
    xbits = np.ones(16, np.int32)
    x = np.concatenate([xbits, 1 - xbits]).astype(np.float32)
    am, om, clm, cm = mk_masks(shape)
    v, _ = infer(state, x, am, om, clm, cm, jnp.float32(15.0))
    assert int(v[0]) == 1
    clm = (np.arange(16) < 14).astype(np.float32)  # clause-number port = 14
    v, _ = infer(state, x, am, om, clm, cm, jnp.float32(15.0))
    assert int(v[0]) == 0


def test_eval_batch_counts_valid_only():
    shape = model.IRIS
    batch = 8
    ev = model.tm_eval_batch(shape, batch)
    state = np.full((3, 16, 32), 99, np.int32)  # predicts 0 everywhere
    xs = np.zeros((batch, 32), np.float32)
    xs[:, 16:] = 1.0
    labels = np.zeros(batch, np.int32)
    labels[4:] = 1  # half the rows are "wrong"
    valid = np.ones(batch, np.float32)
    am, om, clm, cm = mk_masks(shape)
    preds, correct = ev(state, xs, labels, valid, am, om, clm, cm,
                        jnp.float32(15.0))
    assert preds.shape == (batch,)
    assert int(correct) == 4
    # Mask out the wrong half: padding must not count.
    valid[4:] = 0.0
    _, correct = ev(state, xs, labels, valid, am, om, clm, cm,
                    jnp.float32(15.0))
    assert int(correct) == 4


def test_train_step_runs_from_model_entry():
    shape = model.IRIS
    step = model.tm_train_step(shape)
    state = np.full((3, 16, 32), 99, np.int32)
    xbits = np.ones(16, np.int32)
    x = np.concatenate([xbits, 1 - xbits]).astype(np.float32)
    sign = np.array([1.0, -1.0, 0.0], np.float32)
    rng = np.random.default_rng(0)
    clause_rand = rng.random((3, 16)).astype(np.float32)
    ta_rand = rng.random((3, 16, 32)).astype(np.float32)
    am, om, clm, cm = mk_masks(shape)
    scalars = np.array([15.0, 0.27272728, 0.72727275], np.float32)
    new = step(state, x, sign, clause_rand, ta_rand, am, om, clm, cm,
               scalars)
    assert new.shape == (3, 16, 32) and new.dtype == jnp.int32
    assert not np.array_equal(np.asarray(new), state), "feedback applied"


def test_train_epoch_matches_sequential_steps():
    """The lax.scan epoch must equal N sequential fused steps, and all-zero
    sign rows (the padding convention) must be no-ops."""
    shape = model.IRIS
    steps = 6
    epoch = model.tm_train_epoch(shape, steps)
    step = model.tm_train_step(shape)
    rng = np.random.default_rng(11)
    state = rng.integers(0, 200, size=(3, 16, 32)).astype(np.int32)
    am, om, clm, cm = mk_masks(shape)
    scalars = np.array([15.0, 0.2727, 0.7273], np.float32)
    xs, signs, crs, trs = [], [], [], []
    for i in range(steps):
        bits = rng.integers(0, 2, size=16)
        xs.append(np.concatenate([bits, 1 - bits]).astype(np.float32))
        s = np.zeros(3, np.float32)
        if i != 3:  # row 3 is a padding no-op
            t = rng.integers(0, 3)
            s[t] = 1.0
            s[(t + 1) % 3] = -1.0
        signs.append(s)
        crs.append(rng.random((3, 16), dtype=np.float32))
        trs.append(rng.random((3, 16, 32), dtype=np.float32))
    final = epoch(state, np.stack(xs), np.stack(signs), np.stack(crs),
                  np.stack(trs), am, om, clm, cm, scalars)
    cur = state
    for i in range(steps):
        prev = cur
        cur = np.asarray(step(cur, xs[i], signs[i], crs[i], trs[i],
                              am, om, clm, cm, scalars))
        if i == 3:
            np.testing.assert_array_equal(cur, prev, "zero-sign row is a no-op")
    np.testing.assert_array_equal(np.asarray(final), cur)
