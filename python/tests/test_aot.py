"""AOT lowering tests: HLO text is produced, parseable, and the meta
contract matches the model's argument order."""

import json
import os
import subprocess
import sys

import jax

from compile import aot, model


def test_lower_infer_produces_hlo_text():
    shape = model.IRIS
    text = aot.lower(model.tm_infer(shape), model.example_args_infer(shape))
    assert "HloModule" in text
    assert "ROOT" in text


def test_lower_train_produces_hlo_text():
    shape = model.IRIS
    text = aot.lower(model.tm_train_step(shape),
                     model.example_args_train(shape))
    assert "HloModule" in text
    # The train artifact's single output: the [3,16,32] state tensor.
    assert "s32[3,16,32]" in text


def test_arg_specs_order():
    shape = model.IRIS
    specs = aot.arg_specs(model.example_args_train(shape))
    assert specs[0] == {"shape": [3, 16, 32], "dtype": "int32"}
    assert specs[1] == {"shape": [32], "dtype": "float32"}
    assert specs[-1] == {"shape": [3], "dtype": "float32"}  # scalars vec


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--batch", "16"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    meta = json.loads((out / "meta.json").read_text())
    assert meta["shape"]["classes"] == 3
    assert meta["batch"] == 16
    for name, art in meta["artifacts"].items():
        path = out / art["file"]
        assert path.exists(), f"{name} artifact missing"
        assert "HloModule" in path.read_text()[:200]


def test_lowered_infer_executes_via_jax_cpu():
    """Round-trip sanity: the lowered computation compiles and runs on the
    CPU backend (the same backend class the rust PJRT client uses)."""
    import numpy as np
    shape = model.IRIS
    fn = jax.jit(model.tm_infer(shape))
    state = np.full((3, 16, 32), 99, np.int32)
    x = np.zeros(32, np.float32)
    cjl = np.ones((3, 16, 32), np.float32)
    v, pred = fn(state, x, cjl, cjl * 0, np.ones(16, np.float32),
                 np.ones(3, np.float32), np.float32(15.0))
    assert int(pred) == 0
