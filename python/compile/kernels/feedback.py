"""L1 Pallas kernel: the TA feedback update (Type I / Type II).

The RTL applies feedback to every TA combinationally in the second clock
cycle; here it is one elementwise select over the [C, J, L] state tensor,
fused with clause evaluation in a single Pallas invocation so the whole
training step is one VMEM-resident kernel.

Semantics: see the contract in ``rust/src/tm/feedback.rs`` and the oracle
in ``ref.py`` — this kernel must match both bit-for-bit.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _train_kernel(state_ref, x_ref, sign_ref, crand_ref, tarand_ref,
                  and_ref, or_ref, clmask_ref, cmask_ref, scal_ref,
                  new_state_ref, *, thresh: int):
    """Fused clause-eval + feedback. scal_ref = [t, p_reinforce, p_weaken]."""
    state = state_ref[...]
    x = x_ref[...]
    sign = sign_ref[...]
    clause_rand = crand_ref[...]
    ta_rand = tarand_ref[...]
    and_mask = and_ref[...]
    or_mask = or_ref[...]
    clause_mask = clmask_ref[...]
    class_mask = cmask_ref[...]
    t = scal_ref[0]
    p_reinforce = scal_ref[1]
    p_weaken = scal_ref[2]

    # --- clause evaluation, train mode (empty clause fires) ---
    action = (state >= thresh).astype(jnp.float32)
    eff = jnp.minimum(action * and_mask + or_mask, 1.0)          # [C, J, L]
    lit = x[None, None, :]
    blocked = jnp.max(eff * (1.0 - lit), axis=2)                 # [C, J]
    out = (blocked < 0.5).astype(jnp.float32)
    out = out * clause_mask[None, :] * class_mask[:, None]

    # --- clamped votes ---
    j = out.shape[1]
    pol = jnp.where(jnp.arange(j) % 2 == 0, 1.0, -1.0)
    v = jnp.sum(out * pol[None, :], axis=1)
    v = jnp.clip(v, -t, t)                                       # [C] f32

    # --- clause selection ---
    p_sel = (t - sign * v) / (2.0 * t)                           # [C]
    selected = (clause_rand < p_sel[:, None]).astype(jnp.float32)
    selected = selected * (jnp.abs(sign) > 0.5)[:, None] \
        * clause_mask[None, :] * class_mask[:, None]             # [C, J]

    sp = sign[:, None] * pol[None, :]
    type1 = (selected * (sp > 0.5))[:, :, None]                  # [C, J, 1]
    type2 = (selected * (sp < -0.5))[:, :, None]

    # --- per-TA updates ---
    o = out[:, :, None]
    inc1 = type1 * o * lit * (ta_rand < p_reinforce)
    dec1 = type1 * (1.0 - o * lit) * (ta_rand < p_weaken)
    inc2 = type2 * o * (1.0 - lit) * (1.0 - eff)

    delta = (inc1 + inc2 - dec1).astype(jnp.int32)
    new_state_ref[...] = jnp.clip(state + delta, 0, 2 * thresh - 1)


def train_step(state, x, sign, clause_rand, ta_rand,
               and_mask, or_mask, clause_mask, class_mask,
               scalars, *, thresh: int):
    """Fused Pallas training step.

    ``scalars`` = f32[3] vector (t, p_reinforce, p_weaken) — runtime
    controllable (the paper's s/T I/O ports) without re-lowering.
    Returns the new state tensor, i32 [C, J, L].
    """
    return pl.pallas_call(
        partial(_train_kernel, thresh=thresh),
        out_shape=jax.ShapeDtypeStruct(state.shape, jnp.int32),
        interpret=True,
    )(state, x, sign, clause_rand, ta_rand,
      and_mask, or_mask, clause_mask, class_mask, scalars)
