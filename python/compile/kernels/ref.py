"""Pure-jnp oracle for the TM inference/training step.

This is the correctness reference the Pallas kernels (L1) and the fused
model (L2) are tested against, and it mirrors — operation for operation —
the cross-layer contract documented in ``rust/src/tm/feedback.rs``:

* TA action: ``state >= thresh`` (thresh = states-per-side).
* Fault gates on the action outputs: ``eff = (action & and_mask) | or_mask``.
* Clause fires iff every *effective* include's literal is 1; empty clauses
  (no effective includes) fire in TRAIN mode, not in INFER mode.
* Votes: even clause index ⇒ +1, odd ⇒ -1; sums clamped to [-T, T].
* Feedback selection per class with sign ∈ {+1,0,-1}:
  ``p_sel = (T - sign*v) / 2T``; clause selected iff ``clause_rand < p_sel``.
* Type I (sign*polarity = +1):
  - out=1, lit=1: increment iff ``ta_rand < p_reinforce``;
  - out=1, lit=0  or out=0: decrement iff ``ta_rand < p_weaken``.
* Type II (sign*polarity = -1): only if out=1; increment every TA with
  lit=0 whose effective action is exclude.
* All comparisons strict ``<`` on f32; states saturate at [0, 2*thresh-1].

Shapes (iris default): state [C, J, L] i32, x [L] f32, masks [C, J, L] f32,
clause_mask [J] f32, class_mask [C] f32, sign [C] f32,
clause_rand [C, J] f32, ta_rand [C, J, L] f32.
"""

import jax.numpy as jnp


def polarity(n_clauses: int):
    """+1 for even clause indices, -1 for odd (matches rust::tm::params)."""
    return jnp.where(jnp.arange(n_clauses) % 2 == 0, 1, -1).astype(jnp.int32)


def effective_actions(state, and_mask, or_mask, thresh):
    """Post-fault-gate include actions, f32 0/1, shape [C, J, L]."""
    action = (state >= thresh).astype(jnp.float32)
    return jnp.minimum(action * and_mask + or_mask, 1.0)


def clause_outputs(state, x, and_mask, or_mask, clause_mask, class_mask,
                   thresh, train_mode: bool):
    """Clause outputs, f32 0/1, shape [C, J].

    ``train_mode`` selects the empty-clause convention.
    Inactive clauses/classes output 0 in both modes.
    """
    eff = effective_actions(state, and_mask, or_mask, thresh)
    lit = x[None, None, :]
    # Clause fails if any effective include has literal 0.
    blocked = jnp.max(eff * (1.0 - lit), axis=2)  # [C, J]; >0 -> blocked
    fires = (blocked < 0.5).astype(jnp.float32)
    if not train_mode:
        nonempty = (jnp.max(eff, axis=2) > 0.5).astype(jnp.float32)
        fires = fires * nonempty
    return fires * clause_mask[None, :] * class_mask[:, None]


def class_sums(clause_out, t):
    """Clamped per-class vote sums, i32 [C]."""
    pol = polarity(clause_out.shape[1])
    votes = jnp.sum(clause_out.astype(jnp.int32) * pol[None, :], axis=1)
    return jnp.clip(votes, -t, t).astype(jnp.int32)


def infer(state, x, and_mask, or_mask, clause_mask, class_mask, t, thresh):
    """Inference: (clamped sums i32 [C], prediction i32).

    Prediction = argmax over active classes, ties to the lowest index
    (jnp.argmax keeps the first maximum, matching the rust tie-break).
    """
    out = clause_outputs(state, x, and_mask, or_mask, clause_mask,
                         class_mask, thresh, train_mode=False)
    v = class_sums(out, t)
    tmin = jnp.asarray(t, jnp.int32)
    masked = jnp.where(class_mask > 0.5, v, -tmin - 1)
    return v, jnp.argmax(masked).astype(jnp.int32)


def train_step(state, x, sign, clause_rand, ta_rand,
               and_mask, or_mask, clause_mask, class_mask,
               t, p_reinforce, p_weaken, thresh):
    """One training step; returns the new TA state tensor (i32 [C, J, L])."""
    out = clause_outputs(state, x, and_mask, or_mask, clause_mask,
                         class_mask, thresh, train_mode=True)   # [C, J]
    v = class_sums(out, t).astype(jnp.float32)                  # [C]

    tf = jnp.asarray(t, jnp.float32)
    p_sel = (tf - sign * v) / (2.0 * tf)                        # [C]
    selected = (clause_rand < p_sel[:, None]).astype(jnp.float32)
    selected = selected * (jnp.abs(sign) > 0.5)[:, None] \
        * clause_mask[None, :] * class_mask[:, None]            # [C, J]

    pol = polarity(out.shape[1]).astype(jnp.float32)            # [J]
    sp = sign[:, None] * pol[None, :]                           # [C, J]
    type1 = selected * (sp > 0.5)
    type2 = selected * (sp < -0.5)

    lit = x[None, None, :]                                      # [1,1,L]
    o = out[:, :, None]                                         # [C,J,1]
    eff = effective_actions(state, and_mask, or_mask, thresh)   # [C,J,L]

    inc1 = type1[:, :, None] * o * lit * (ta_rand < p_reinforce)
    dec1 = type1[:, :, None] * (1.0 - o * lit) * (ta_rand < p_weaken)
    inc2 = type2[:, :, None] * o * (1.0 - lit) * (1.0 - eff)

    delta = (inc1 + inc2 - dec1).astype(jnp.int32)
    return jnp.clip(state + delta, 0, 2 * thresh - 1)
