"""L1 Pallas kernel: clause evaluation + (optionally) votes.

The paper's compute hot-spot is the fully-parallel clause bank: every
clause ANDs its included literals in one cycle, the adder tree sums the
votes in the next (§6: "two clock cycles to complete inference and
feedback for all clauses and TAs"). On TPU this becomes a masked reduction
over the literal axis, vectorised on the VPU, with the whole
``[classes, clauses, literals]`` tile resident in VMEM (see DESIGN.md
§Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the AOT artifact runs
on the rust CPU client. Real-TPU compilation would use the same BlockSpecs.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _clause_kernel(state_ref, x_ref, and_ref, or_ref, clmask_ref, cmask_ref,
                   out_ref, *, thresh: int, train_mode: bool):
    """Whole-machine clause evaluation in one grid step.

    VMEM footprint (iris): 3*16*32 i32 state + 3 masks of the same shape
    + literals  ≈ 25 KiB — far below VMEM; one tile, no HBM round-trips.
    """
    state = state_ref[...]
    x = x_ref[...]
    and_mask = and_ref[...]
    or_mask = or_ref[...]
    clause_mask = clmask_ref[...]
    class_mask = cmask_ref[...]

    action = (state >= thresh).astype(jnp.float32)
    eff = jnp.minimum(action * and_mask + or_mask, 1.0)      # [C, J, L]
    lit = x[None, None, :]                                    # [1, 1, L]
    blocked = jnp.max(eff * (1.0 - lit), axis=2)              # [C, J]
    fires = (blocked < 0.5).astype(jnp.float32)
    if not train_mode:
        nonempty = (jnp.max(eff, axis=2) > 0.5).astype(jnp.float32)
        fires = fires * nonempty
    out_ref[...] = fires * clause_mask[None, :] * class_mask[:, None]


def clause_outputs(state, x, and_mask, or_mask, clause_mask, class_mask,
                   *, thresh: int, train_mode: bool):
    """Pallas clause bank: returns f32 0/1 outputs, shape [C, J]."""
    c, j, _ = state.shape
    return pl.pallas_call(
        partial(_clause_kernel, thresh=thresh, train_mode=train_mode),
        out_shape=jax.ShapeDtypeStruct((c, j), jnp.float32),
        interpret=True,
    )(state, x, and_mask, or_mask, clause_mask, class_mask)


def votes(clause_out, t):
    """Polarity-weighted vote reduction (the RTL adder tree), clamped.

    Kept outside the Pallas kernel body as a separate fusable reduction —
    XLA fuses it with the kernel output; on real TPU a batched variant
    feeds the MXU as a [1,J]x[J,1] contraction.
    """
    j = clause_out.shape[1]
    pol = jnp.where(jnp.arange(j) % 2 == 0, 1, -1).astype(jnp.int32)
    v = jnp.sum(clause_out.astype(jnp.int32) * pol[None, :], axis=1)
    ti = t.astype(jnp.int32) if hasattr(t, "astype") else jnp.int32(t)
    return jnp.clip(v, -ti, ti).astype(jnp.int32)
