"""AOT lowering: JAX → HLO **text** artifacts for the rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts``

Emits:
  tm_infer.hlo.txt        — single-datapoint inference
  tm_train.hlo.txt        — single-datapoint training step
  tm_eval_batch.hlo.txt   — padded-batch accuracy analysis
  meta.json               — shapes/arg-order contract for the rust side
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def arg_specs(example_args):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=150,
                    help="eval-batch padding size")
    ap.add_argument("--epoch-steps", type=int, default=60,
                    help="scan length of the train-epoch artifact")
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--clauses", type=int, default=16)
    ap.add_argument("--features", type=int, default=16)
    ap.add_argument("--states", type=int, default=100)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    shape = model.TmShape(classes=args.classes, clauses=args.clauses,
                          features=args.features, states=args.states)
    jobs = {
        "tm_infer": (model.tm_infer(shape), model.example_args_infer(shape)),
        "tm_train": (model.tm_train_step(shape),
                     model.example_args_train(shape)),
        "tm_train_epoch": (model.tm_train_epoch(shape, args.epoch_steps),
                           model.example_args_epoch(shape, args.epoch_steps)),
        "tm_eval_batch": (model.tm_eval_batch(shape, args.batch),
                          model.example_args_eval(shape, args.batch)),
    }

    meta = {
        "shape": {
            "classes": shape.classes,
            "clauses": shape.clauses,
            "features": shape.features,
            "states": shape.states,
        },
        "batch": args.batch,
        "epoch_steps": args.epoch_steps,
        "artifacts": {},
    }
    for name, (fn, ex) in jobs.items():
        text = lower(fn, ex)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_specs(ex),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
