"""L2: the TM compute graph in JAX, calling the L1 Pallas kernels.

Three entry points, each AOT-lowered by ``aot.py`` to an HLO-text artifact
the rust runtime executes via PJRT (python never runs at request time):

* ``tm_infer``      — one datapoint → (clamped class sums, prediction).
* ``tm_train_step`` — one labelled datapoint + explicit randomness → new
                      TA states (the online/offline learning step).
* ``tm_eval_batch`` — padded batch → predictions + correct count (the
                      accuracy-analysis block, §3.3, evaluated in one
                      dispatch).

Structural hyper-parameters (classes/clauses/features/states) are baked at
lowering time, mirroring the paper's pre-synthesis parameters; run-time
hyper-parameters (s via p_reinforce/p_weaken, T, the clause-number port,
the class mask, fault gates) are graph *inputs*, mirroring the paper's
run-time I/O ports — changing them needs no re-lowering, exactly as the
FPGA needs no re-synthesis.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels import clause as kclause
from compile.kernels import feedback as kfeedback


@dataclass(frozen=True)
class TmShape:
    """Pre-synthesis (structural) parameters — must match
    ``rust/src/tm/params.rs::TmShape``."""
    classes: int = 3
    clauses: int = 16
    features: int = 16
    states: int = 100  # per action side; include threshold

    @property
    def literals(self) -> int:
        return 2 * self.features


IRIS = TmShape()


def tm_infer(shape: TmShape):
    """Build the inference function for a given structural shape."""

    def infer(state, x, and_mask, or_mask, clause_mask, class_mask, t):
        out = kclause.clause_outputs(
            state, x, and_mask, or_mask, clause_mask, class_mask,
            thresh=shape.states, train_mode=False)
        v = kclause.votes(out, t)
        ti = t.astype(jnp.int32)
        masked = jnp.where(class_mask > 0.5, v, -ti - 1)
        return v, jnp.argmax(masked).astype(jnp.int32)

    return infer


def tm_train_step(shape: TmShape):
    """Build the training-step function (fused Pallas kernel)."""

    def step(state, x, sign, clause_rand, ta_rand,
             and_mask, or_mask, clause_mask, class_mask, scalars):
        return kfeedback.train_step(
            state, x, sign, clause_rand, ta_rand,
            and_mask, or_mask, clause_mask, class_mask, scalars,
            thresh=shape.states)

    return step


def tm_eval_batch(shape: TmShape, batch: int = 150):
    """Build the batched accuracy-analysis function.

    Inputs are padded to ``batch`` rows; ``valid`` masks the padding.
    Returns (predictions i32[batch], correct-count i32).
    """

    infer = tm_infer(shape)

    def eval_batch(state, xs, labels, valid,
                   and_mask, or_mask, clause_mask, class_mask, t):
        def one(x):
            return infer(state, x, and_mask, or_mask,
                         clause_mask, class_mask, t)[1]

        preds = jax.vmap(one)(xs)                       # [B]
        correct = jnp.sum(
            ((preds == labels) & (valid > 0.5)).astype(jnp.int32))
        return preds, correct

    return eval_batch


def tm_train_epoch(shape: TmShape, steps: int = 60):
    """Build the scan-over-datapoints training pass.

    Executes ``steps`` training steps in ONE dispatch (jax.lax.scan over
    the fused Pallas step), amortising PJRT call overhead — the L2
    optimisation recorded in EXPERIMENTS.md §Perf. Rows beyond a shorter
    pass are padded with an all-zero ``sign`` vector, which makes the
    step a provable no-op (no clause is ever selected).
    """
    import jax

    step = tm_train_step(shape)

    def epoch(state, xs, signs, clause_rands, ta_rands,
              and_mask, or_mask, clause_mask, class_mask, scalars):
        def body(carry, inp):
            x, sign, cr, tr = inp
            new = step(carry, x, sign, cr, tr,
                       and_mask, or_mask, clause_mask, class_mask, scalars)
            return new, ()

        final, _ = jax.lax.scan(
            body, state, (xs, signs, clause_rands, ta_rands))
        return final

    return epoch


def example_args_epoch(shape: TmShape, steps: int = 60):
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    cjl = (shape.classes, shape.clauses, shape.literals)
    return (
        s(cjl, i32),                                      # state
        s((steps, shape.literals), f32),                  # xs
        s((steps, shape.classes), f32),                   # signs
        s((steps, shape.classes, shape.clauses), f32),    # clause_rands
        s((steps,) + cjl, f32),                           # ta_rands
        s(cjl, f32),                                      # and_mask
        s(cjl, f32),                                      # or_mask
        s((shape.clauses,), f32),                         # clause_mask
        s((shape.classes,), f32),                         # class_mask
        s((3,), f32),                                     # scalars
    )


def example_args_infer(shape: TmShape):
    """ShapeDtypeStructs in the exact argument order of ``tm_infer``."""
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    cjl = (shape.classes, shape.clauses, shape.literals)
    return (
        s(cjl, i32),                     # state
        s((shape.literals,), f32),       # x
        s(cjl, f32),                     # and_mask
        s(cjl, f32),                     # or_mask
        s((shape.clauses,), f32),        # clause_mask
        s((shape.classes,), f32),        # class_mask
        s((), f32),                      # t
    )


def example_args_train(shape: TmShape):
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    cjl = (shape.classes, shape.clauses, shape.literals)
    return (
        s(cjl, i32),                                  # state
        s((shape.literals,), f32),                    # x
        s((shape.classes,), f32),                     # sign
        s((shape.classes, shape.clauses), f32),       # clause_rand
        s(cjl, f32),                                  # ta_rand
        s(cjl, f32),                                  # and_mask
        s(cjl, f32),                                  # or_mask
        s((shape.clauses,), f32),                     # clause_mask
        s((shape.classes,), f32),                     # class_mask
        s((3,), f32),                                 # scalars [t, p_re, p_wk]
    )


def example_args_eval(shape: TmShape, batch: int = 150):
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    cjl = (shape.classes, shape.clauses, shape.literals)
    return (
        s(cjl, i32),                     # state
        s((batch, shape.literals), f32), # xs
        s((batch,), i32),                # labels
        s((batch,), f32),                # valid
        s(cjl, f32),                     # and_mask
        s(cjl, f32),                     # or_mask
        s((shape.clauses,), f32),        # clause_mask
        s((shape.classes,), f32),        # class_mask
        s((), f32),                      # t
    )
